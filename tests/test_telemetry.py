"""Telemetry-plane tests (DESIGN.md §12): metrics registry + Prometheus
rendering, span propagation across every serving path (batched,
singleton fast path, measured-wire twin, cluster), codec round-trips of
span headers and metrics frames, TCP RTT histograms, and the live
SE-drift monitor — including the tier-2 acceptance criterion that a
mis-rated solve is flagged while clean solves pass."""
import dataclasses
import io
import json
import math
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.denoisers import BernoulliGauss
from repro.serving import (BucketPolicy, ClusterService, PrewarmSpec,
                           RouterPolicy, SolveRequest, SolveService,
                           decode_metrics, decode_request, encode_metrics,
                           encode_request, encode_result, decode_result)
from repro.serving.frontend import BackendServer, LocalBackend, TcpBackend
from repro.telemetry import (DRIFT_ALERT, MetricsRegistry, hist_quantile,
                             merge_snapshots, prometheus_text, se_drift,
                             se_prediction)
from repro.telemetry.spans import (chrome_trace_events, expected_spans,
                                   missing_spans, span, span_names,
                                   spans_monotonic, tag_host,
                                   write_trace_jsonl)

POL = BucketPolicy(max_batch=8, n_quantum=64, mp_quantum=8)


def make_reqs(n_req, n=128, m=64, p=4, t=8, seed=0, snr_db=20.0,
              declared_snr=None, policy="fixed", **req_kw):
    """Requests whose data is generated at ``snr_db`` but *declared* at
    ``declared_snr`` (defaults to the truth) — the mis-rated knob for the
    drift tests."""
    import jax

    from repro.core.amp import sample_problem
    from repro.core.state_evolution import CSProblem

    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=snr_db)
    deltas = None
    if policy == "fixed":
        deltas = np.full(t, 0.05, np.float32)
        deltas[0] = np.inf
    reqs = []
    for i in range(n_req):
        _, a, y = sample_problem(jax.random.PRNGKey(seed + i), n, m, prior,
                                 prob.sigma_e2)
        reqs.append(SolveRequest(
            y=y, a=a, prior=prior, n_proc=p, n_iter=t, policy=policy,
            deltas=deltas,
            snr_db=declared_snr if declared_snr is not None else snr_db,
            **req_kw))
    return prior, reqs


# ---------------------------------------------------------------------------
# metrics registry units
# ---------------------------------------------------------------------------

def test_counter_gauge_labels_and_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("amp_requests_total", "requests", ("layout",))
    g = reg.gauge("amp_inflight", "in flight")
    c.inc(layout="row")
    c.inc(2.0, layout="row")
    c.inc(layout="col")
    g.set(7.0)
    snap = reg.snapshot()
    by_name = {m["name"]: m for m in snap["metrics"]}
    assert by_name["amp_requests_total"]["kind"] == "counter"
    samples = {tuple(s["labels"].items()): s["value"]
               for s in by_name["amp_requests_total"]["samples"]}
    assert samples == {(("layout", "col"),): 1.0, (("layout", "row"),): 3.0}
    assert by_name["amp_inflight"]["samples"] == [{"labels": {}, "value": 7.0}]
    # label mismatch and re-registration with a different shape both fail
    with pytest.raises(ValueError):
        c.inc(host="x")
    with pytest.raises(ValueError):
        reg.gauge("amp_requests_total")
    with pytest.raises(ValueError):
        reg.counter("amp_requests_total", labelnames=("host",))
    # same name + same shape returns the same metric object
    assert reg.counter("amp_requests_total", labelnames=("layout",)) is c
    # set_total is absolute assignment (collector mirroring), not adding
    c.set_total(10.0, layout="row")
    assert reg.snapshot()["metrics"][-1]["samples"][-1]["value"] == 10.0


def test_histogram_counts_and_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("amp_lat_seconds", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    (s,) = reg.snapshot()["metrics"][0]["samples"]
    assert s["bounds"] == [0.01, 0.1, 1.0]
    assert s["counts"] == [1, 2, 1, 1]          # last bucket = +Inf overflow
    assert s["count"] == 5 and s["sum"] == pytest.approx(5.605)
    assert hist_quantile(s, 0.5) == 0.1
    assert hist_quantile(s, 0.95) == 1.0        # +Inf reports largest bound
    assert hist_quantile({"count": 0, "bounds": [], "counts": []}, 0.5) is None
    with pytest.raises(ValueError):
        reg.histogram("amp_bad", buckets=())


def test_registry_thread_safety():
    """Concurrent increments/observations from many threads lose nothing
    and snapshots taken mid-flight are never torn (count == sum of bucket
    counts)."""
    reg = MetricsRegistry()
    c = reg.counter("amp_n_total")
    h = reg.histogram("amp_v", buckets=(0.5,))
    n_threads, per_thread = 8, 2000

    def work():
        for i in range(per_thread):
            c.inc()
            h.observe((i % 2) * 1.0)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for _ in range(50):                         # reads racing the writers
        (s,) = reg.snapshot()["metrics"][1]["samples"] or [
            {"counts": [0, 0], "count": 0}]
        assert sum(s["counts"]) == s["count"]
    for t in threads:
        t.join()
    snap = reg.snapshot()
    by_name = {m["name"]: m for m in snap["metrics"]}
    assert by_name["amp_n_total"]["samples"][0]["value"] == \
        n_threads * per_thread
    assert by_name["amp_v"]["samples"][0]["count"] == n_threads * per_thread


def test_prometheus_text_rendering():
    reg = MetricsRegistry()
    reg.counter("amp_x_total", "help text", ("k",)).inc(3, k='a"b\\c')
    reg.histogram("amp_h", buckets=(1.0, 2.0)).observe(1.5)
    text = prometheus_text(reg.snapshot())
    lines = text.strip().splitlines()
    assert "# TYPE amp_h histogram" in lines
    assert 'amp_h_bucket{le="1"} 0' in lines
    assert 'amp_h_bucket{le="2"} 1' in lines
    assert 'amp_h_bucket{le="+Inf"} 1' in lines
    assert "amp_h_sum 1.5" in lines and "amp_h_count 1" in lines
    assert "# HELP amp_x_total help text" in lines
    # label values escaped per the exposition format
    assert r'amp_x_total{k="a\"b\\c"} 3' in lines
    assert prometheus_text({"metrics": []}) == ""


def test_merge_snapshots_adds_host_label():
    def one(v):
        r = MetricsRegistry()
        r.counter("amp_c_total", labelnames=("layout",)).inc(v, layout="row")
        return r.snapshot()

    merged = merge_snapshots([("h0", one(1)), ("h1", one(2))])
    (m,) = merged["metrics"]
    assert m["labelnames"] == ["host", "layout"]
    assert [(s["labels"]["host"], s["value"]) for s in m["samples"]] == \
        [("h0", 1.0), ("h1", 2.0)]
    # merged output renders (host= label on every series, no summing)
    assert 'amp_c_total{host="h0",layout="row"} 1' in \
        prometheus_text(merged)


# ---------------------------------------------------------------------------
# span helpers + codec round-trips
# ---------------------------------------------------------------------------

def test_span_vocabulary_helpers():
    assert expected_spans() == ["admit", "batch_wait", "operands",
                                "compute", "complete"]
    assert expected_spans(wire=True)[-2:] == ["wire_measure", "complete"]
    assert expected_spans(cluster=True)[1] == "route"
    spans = [span(n, i, i + 0.5) for i, n in enumerate(expected_spans())]
    assert missing_spans(spans) == []
    assert missing_spans(spans, wire=True) == ["wire_measure"]
    assert missing_spans(None) == expected_spans()
    assert spans_monotonic(spans) and spans_monotonic(None)
    assert not spans_monotonic([span("a", 1.0, 0.5)])        # t1 < t0
    assert not spans_monotonic([span("a", 2.0, 3.0), span("b", 1.0, 4.0)])
    # per-host ordering: interleaved hosts are each monotone on their own
    assert spans_monotonic([span("a", 5.0, 6.0, host="x"),
                            span("b", 1.0, 2.0, host="y"),
                            span("c", 6.0, 7.0, host="x")])
    assert tag_host([["a", None, 0.0, 1.0], ["b", "h", 1.0, 2.0]], "z") == \
        [["a", "z", 0.0, 1.0], ["b", "h", 1.0, 2.0]]


def test_chrome_trace_export():
    spans = [span("admit", 1.0, 1.5, host="frontend"),
             span("compute", 2.0, 2.25)]
    evs = chrome_trace_events(7, spans)
    assert evs[0] == {"name": "admit", "ph": "X", "pid": "frontend",
                      "tid": 7, "ts": 1e6, "dur": 0.5e6, "cat": "amp"}
    assert evs[1]["pid"] == "local"
    fp = io.StringIO()
    import types
    n = write_trace_jsonl(fp, [
        types.SimpleNamespace(request_id=7, spans=spans),
        types.SimpleNamespace(request_id=8, spans=None)])
    assert n == 2
    parsed = [json.loads(l) for l in fp.getvalue().splitlines()]
    assert [e["name"] for e in parsed] == ["admit", "compute"]


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["admit", "route", "compute"]),
    st.sampled_from([None, "frontend", "host0"]),
    st.floats(0.0, 1e6, allow_nan=False),
    st.floats(0.0, 1e6, allow_nan=False)), max_size=6),
    st.floats(0.0, 10.0, allow_nan=False))
def test_codec_span_and_drift_headers_roundtrip(raw, drift):
    """Spans and se_drift ride codec JSON headers bit-exactly in both
    directions (request and result frames)."""
    spans = [span(n, t0, t1, host=h) for n, h, t0, t1 in raw]
    _, (req,) = make_reqs(1)
    req = dataclasses.replace(req)
    req.spans = [list(s) for s in spans]
    back = decode_request(encode_request(req))
    assert back.spans == spans

    res = dataclasses.replace(_solved_singleton(), se_drift=float(drift),
                              spans=[list(s) for s in spans] or None)
    back = decode_result(encode_result(res))
    assert back.se_drift == float(drift)
    assert back.spans == res.spans


_SINGLETON_CACHE = []


def _solved_singleton():
    if not _SINGLETON_CACHE:
        svc = SolveService(policy=POL, rate_accounting=False)
        _, reqs = make_reqs(1, seed=77)
        _SINGLETON_CACHE.append(svc.solve(reqs)[0])
    return _SINGLETON_CACHE[0]


def test_codec_metrics_frame_roundtrip():
    reg = MetricsRegistry()
    reg.counter("amp_c_total", "c", ("layout",)).inc(2, layout="row")
    reg.histogram("amp_h", buckets=(0.1, 1.0)).observe(0.5)
    snap = reg.snapshot()
    host, back = decode_metrics(encode_metrics("host3", snap))
    assert host == "host3" and back == snap
    # strict frame validation: wrong kind, junk fields, bad payloads
    from repro.serving.codec import CodecError, _pack, _unpack
    _, reqs = make_reqs(1)
    with pytest.raises(CodecError):
        decode_metrics(encode_request(reqs[0]))    # not a metrics frame
    buf = encode_metrics("h", snap)
    header, arrays = _unpack(buf)
    header["extra"] = 1
    with pytest.raises(CodecError):
        decode_metrics(_pack(header, arrays))
    with pytest.raises(CodecError):
        decode_metrics(_pack({"kind": "metrics", "host": "h",
                              "metrics": {"metrics": "nope"}}, {}))


# ---------------------------------------------------------------------------
# spans + drift through the solve service (every dispatch path)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def telem_svc():
    svc = SolveService(policy=POL, rate_accounting=False)
    _, reqs = make_reqs(8)
    return svc, svc.solve(reqs)


def test_batched_path_span_tree(telem_svc):
    svc, results = telem_svc
    for r in results:
        assert r.batch_size == 8
        assert missing_spans(r.spans) == []
        assert span_names(r.spans) == expected_spans()
        assert spans_monotonic(r.spans), r.spans
        # all spans on one (local) host until a cluster tags them
        assert {s[1] for s in r.spans} == {None}
    # the batch-execution spans are shared verbatim across the group
    ops = {tuple(s) for r in results for s in r.spans if s[0] == "operands"}
    assert len(ops) == 1


def test_batched_path_drift_clean(telem_svc):
    """Clean solves (true SNR declared) have well-defined drift and a
    typical value well under the alert line. Per-request bounds are NOT
    asserted here: at n=128/m=64 individual AMP realizations deviate
    from SE heavily (the monitor is advisory for a reason) — the tier-2
    acceptance test pins the clean/mis-rated separation at n=512."""
    _, results = telem_svc
    drifts = [r.se_drift for r in results]
    assert all(d is not None and math.isfinite(d) for d in drifts), drifts
    assert float(np.median(drifts)) < 0.75, drifts


def test_service_metrics_surface(telem_svc):
    svc, results = telem_svc
    snap = svc.metrics()
    by_name = {m["name"]: m for m in snap["metrics"]}
    req_total = sum(s["value"]
                    for s in by_name["amp_requests_total"]["samples"])
    assert req_total >= len(results)
    (lat,) = [s for s in by_name["amp_request_latency_seconds"]["samples"]
              if s["labels"]["layout"] == "row"]
    assert lat["count"] >= len(results)
    assert sum(lat["counts"]) == lat["count"]
    (dr,) = by_name["amp_se_drift"]["samples"]
    assert dr["count"] >= len(results)
    # collector-pulled engine/cache counters are present and consistent
    comp = sum(s["value"]
               for s in by_name["amp_engine_compiles_total"]["samples"])
    assert comp == svc.compile_count() > 0
    assert "amp_operand_cache_hits_total" in by_name
    text = svc.metrics_text()
    assert "# TYPE amp_request_latency_seconds histogram" in text
    assert "amp_se_drift_bucket" in text


def test_singleton_fast_path_span_tree():
    """The singleton fast path (lone lossless row request) emits the same
    complete span vocabulary as the batched path."""
    svc = SolveService(policy=POL, rate_accounting=False)
    _, (req,) = make_reqs(1, seed=30)
    req = dataclasses.replace(req, policy="lossless", deltas=None)
    svc.submit(req)
    (res,) = svc.flush()
    assert res.batch_size == 1
    assert svc.stats()["singleton_dispatches"] == 1
    assert missing_spans(res.spans) == []
    assert spans_monotonic(res.spans), res.spans
    assert res.se_drift is not None and res.se_drift < DRIFT_ALERT


def test_measure_wire_span_tree():
    """The measured-wire engine twin adds the wire_measure span and keeps
    the tree monotone (the complete span starts after coding ends)."""
    svc = SolveService(policy=POL, rate_accounting=False)
    _, reqs = make_reqs(2, seed=40, measure_wire=True)
    results = svc.solve(reqs)
    for r in results:
        assert r.bytes_on_wire is not None
        assert missing_spans(r.spans, wire=True) == []
        assert span_names(r.spans) == expected_spans(wire=True)
        assert spans_monotonic(r.spans), r.spans


def test_telemetry_off_is_clean():
    svc = SolveService(policy=POL, rate_accounting=False, telemetry=False)
    _, reqs = make_reqs(2, seed=60)
    results = svc.solve(reqs)
    for r in results:
        assert r.spans is None and r.se_drift is None
    assert svc.metrics() == {"metrics": []}
    assert svc.metrics_text() == ""


# ---------------------------------------------------------------------------
# cluster: cross-host span trees, metrics aggregation, TCP RTT
# ---------------------------------------------------------------------------

def test_cluster_span_tree_and_merged_metrics():
    prior, reqs = make_reqs(16, seed=100)
    cl = ClusterService(n_hosts=2, policy=POL,
                        router_policy=RouterPolicy(min_replicas=2),
                        rate_accounting=False)
    try:
        results = sorted(cl.solve(reqs), key=lambda r: r.request_id)
        hosts_seen = set()
        for r in results:
            assert missing_spans(r.spans, cluster=True) == []
            # frontend admit/route, then the backend's own full tree
            # (its admit re-stamps on the backend clock)
            assert span_names(r.spans) == \
                ["admit", "route"] + expected_spans()
            assert spans_monotonic(r.spans), r.spans
            # frontend spans tagged "frontend"; backend spans tagged with
            # the routed host (never None after _absorb)
            assert r.spans[0][1] == r.spans[1][1] == "frontend"
            backend_hosts = {s[1] for s in r.spans[2:]}
            assert len(backend_hosts) == 1
            assert backend_hosts < {"host0", "host1"}
            hosts_seen |= backend_hosts
        assert hosts_seen == {"host0", "host1"}
        # merged snapshot: frontend + per-host series under a host label
        snap = cl.metrics()
        by_name = {m["name"]: m for m in snap["metrics"]}
        sub = by_name["amp_cluster_submitted_total"]["samples"]
        assert [(s["labels"]["host"], s["value"]) for s in sub] == \
            [("frontend", float(len(reqs)))]
        lat_hosts = {s["labels"]["host"]
                     for s in by_name["amp_request_latency_seconds"]
                     ["samples"]}
        assert lat_hosts == {"host0", "host1"}
        served = {s["labels"]["host"]: s["value"]
                  for s in by_name["amp_router_served_total"]["samples"]}
        assert served == {"host0": 8.0, "host1": 8.0}
        text = cl.metrics_text()
        assert 'amp_requests_total{host="host0",layout="row"}' in text
    finally:
        cl.close()


def test_tcp_metrics_frame_and_rtt():
    """The b"M" frame pulls a remote host's snapshot over the wire, and
    TcpBackend times every frame kind into its RTT histograms — surfaced
    as amp_tcp_* series on the frontend registry."""
    prior, reqs = make_reqs(16, seed=120)
    server = BackendServer(LocalBackend(
        "host1", SolveService(policy=POL, rate_accounting=False)))
    server.start()
    try:
        tcp = TcpBackend((server.host, server.port), "host1")
        cl = ClusterService(
            backends=[LocalBackend("host0",
                                   SolveService(policy=POL,
                                                rate_accounting=False)),
                      tcp],
            policy=POL, router_policy=RouterPolicy(min_replicas=2))
        results = sorted(cl.solve(reqs), key=lambda r: r.request_id)
        assert len(results) == len(reqs)
        # remote snapshot crossed the wire as a codec frame
        snap = tcp.metrics()
        names = {m["name"] for m in snap["metrics"]}
        assert "amp_requests_total" in names
        # RTT histograms recorded per frame kind, in milliseconds
        rtt = tcp.rtt_stats()
        assert rtt["S"]["count"] >= 8            # submits crossed the wire
        assert rtt["M"]["count"] >= 1
        for s in rtt.values():
            assert 0.0 <= s["p50_ms"] <= s["p95_ms"] <= s["max_ms"]
        assert cl.rtt_stats() == {"host1": rtt}
        # frontend collector folds RTT quantiles into the merged snapshot
        text = cl.metrics_text()
        assert 'amp_tcp_rtt_p95_seconds{host="host1",op="S"}' in text
        assert 'amp_requests_total{host="host1",layout="row"}' in text
        cl.close(shutdown_remote=True)
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# SE drift unit + tier-2 acceptance (mis-rated solve flagged)
# ---------------------------------------------------------------------------

def test_se_prediction_memoized():
    from repro.core.state_evolution import CSProblem

    prob = CSProblem(n=512, m=160, prior=BernoulliGauss(eps=0.1),
                     snr_db=20.0)
    ev = np.full(6, 1e-3)
    p1 = se_prediction(prob, 6, ev, n_proc=5)
    p2 = se_prediction(prob, 6, ev + 1e-9, n_proc=5)    # same rounded key
    assert p1 is p2
    p3 = se_prediction(prob, 6, ev * 2.0, n_proc=5)     # real change: miss
    assert p3 is not p1
    # col layout predictions exist and differ from row
    pc = se_prediction(prob, 6, ev, layout="col", n_proc=5)
    assert pc.shape == (6,) and not np.allclose(pc, p1)
    # drift of the prediction against itself is ~0
    d, _ = se_drift(prob, p1, ev, n_proc=5)
    assert d == pytest.approx(0.0, abs=1e-12)
    d_nan, _ = se_drift(prob, np.zeros(6), ev, n_proc=5)
    assert math.isnan(d_nan)


@pytest.mark.tier2
def test_drift_monitor_flags_misrated_solve():
    """Acceptance (ISSUE 9): requests that declare the wrong operating
    point (data generated at 20 dB, declared 40 dB) trip the drift alert;
    the clean half of the same stream passes. Larger instances than the
    span tests (n=512) keep the clean population concentrated well away
    from the alert line, and lossless transport makes the late-iteration
    variance floor purely noise-determined — so the 100x sigma_e2
    mis-declaration shows up at full strength instead of hiding under
    quantization noise."""
    svc = SolveService(policy=POL, rate_accounting=False)
    _, clean = make_reqs(8, n=512, m=256, t=10, seed=200,
                         policy="lossless")
    _, misrated = make_reqs(8, n=512, m=256, t=10, seed=300, snr_db=20.0,
                            declared_snr=40.0, policy="lossless")
    res_clean = svc.solve(clean)
    res_bad = svc.solve(misrated)
    for r in res_clean:
        assert r.se_drift is not None and r.se_drift < DRIFT_ALERT
    for r in res_bad:
        assert r.se_drift is not None and r.se_drift > DRIFT_ALERT, \
            r.se_drift
    by_name = {m["name"]: m for m in svc.metrics()["metrics"]}
    alerts = sum(s["value"]
                 for s in by_name["amp_se_drift_alerts_total"]["samples"])
    assert alerts == len(res_bad)
    # the drift histogram separates the populations: p95 over the mixed
    # stream exceeds what the clean half alone would produce
    (dr,) = by_name["amp_se_drift"]["samples"]
    assert hist_quantile(dr, 0.95) >= DRIFT_ALERT
