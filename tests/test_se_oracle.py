"""SE-oracle test (ISSUE 2): Monte-Carlo engine MSE trajectories must track
quantized state evolution (paper eq. 8) at every iteration — the paper's
central claim, checked end-to-end through the scan-compiled engine.

Alignment: the estimate produced at scan iteration t is x_{t+1}, whose
large-system MSE is kappa * (sigma_{t+1}^2 - sigma_e^2) under the SE
recursion. At N=2000 the MC average sits systematically *above* the
N->infinity SE value (finite-size AMP deviations compound per iteration:
measured ~7% at t=0 growing to ~27% at t=7 for the lossless path, halving
when N doubles), so the tolerance grows linearly in t with ~20% headroom
over the measured bias. A real accounting bug — e.g. dropping the
P*sigma_Q^2 fusion-noise term — shifts the quantized trajectory by far
more than this envelope.
"""
import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                               ExactFusion, FixedSchedule)
from repro.core.state_evolution import CSProblem, se_trajectory_quantized

pytestmark = pytest.mark.tier2

N, M, P, T, B = 2000, 600, 10, 8, 24
REL_TOL = 0.12 + 0.03 * np.arange(T)   # calibrated finite-N envelope


@pytest.fixture(scope="module")
def mc_ctx():
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=N, m=M, prior=prior, snr_db=20.0)
    insts = [sample_problem(jax.random.PRNGKey(i), N, M, prior,
                            prob.sigma_e2) for i in range(B)]
    s0s = np.stack([i[0] for i in insts])
    a_mats = np.stack([i[1] for i in insts])
    ys = np.stack([i[2] for i in insts])
    mm = make_mmse_interp(prior)
    return prob, mm, s0s, a_mats, ys


def _mc_mse(prob, transport, deltas, s0s, a_mats, ys):
    eng = AmpEngine(prob.prior,
                    EngineConfig(n_proc=P, n_iter=T, collect_symbols=False),
                    transport, FixedSchedule(deltas))
    return eng.solve_many(ys, a_mats).mse(s0s).mean(axis=0)


def _se_mse(prob, mm, sigma_q2):
    traj = se_trajectory_quantized(prob, sigma_q2, P, mmse_fn=mm)
    return prob.kappa * (traj[1:] - prob.sigma_e2)


def test_exact_fusion_tracks_centralized_se(mc_ctx):
    """Lossless fusion: MC == SE with sigma_Q^2 = 0 (paper eq. 4)."""
    prob, mm, s0s, a_mats, ys = mc_ctx
    deltas = np.full(T, np.inf, np.float32)
    mc = _mc_mse(prob, ExactFusion(), deltas, s0s, a_mats, ys)
    se = _se_mse(prob, mm, np.zeros(T))
    rel = np.abs(mc - se) / se
    assert (rel < REL_TOL).all(), list(zip(rel, REL_TOL))


def test_ecsq_transport_tracks_quantized_se(mc_ctx):
    """ECSQ fusion at fixed bins: MC == SE with sigma_Q^2 = Delta^2/12
    injected as P*sigma_Q^2 (paper eq. 8)."""
    prob, mm, s0s, a_mats, ys = mc_ctx
    deltas = np.concatenate([[np.inf],
                             np.full(T - 1, 0.08)]).astype(np.float32)
    mc = _mc_mse(prob, EcsqTransport(), deltas, s0s, a_mats, ys)
    sigma_q2 = np.where(np.isfinite(deltas), deltas ** 2 / 12.0, 0.0)
    se = _se_mse(prob, mm, sigma_q2)
    rel = np.abs(mc - se) / se
    assert (rel < REL_TOL).all(), list(zip(rel, REL_TOL))

    # the oracle has teeth: the quantized trajectory must separate from the
    # lossless one by far more than the tolerance envelope at steady state
    mc_exact = _mc_mse(prob, ExactFusion(), np.full(T, np.inf, np.float32),
                       s0s, a_mats, ys)
    assert mc[-1] > 1.2 * mc_exact[-1], (mc[-1], mc_exact[-1])
    se_exact = _se_mse(prob, mm, np.zeros(T))
    assert se[-1] > 1.2 * se_exact[-1]
