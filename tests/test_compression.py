import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.compression import (QuantConfig, dequantize_blocks, pack_int4,
                                    quantize_blocks, unpack_int4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 4096))
def test_int4_pack_roundtrip(seed, n):
    n = n * 2  # even
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-7, 8, n), jnp.int8)
    assert (unpack_int4(pack_int4(q)) == q).all()


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e4])
def test_quant_error_bound(bits, scale):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 2048)).astype(np.float32)) * scale
    qc = QuantConfig(bits=bits, block=256)
    q, s = quantize_blocks(x, qc)
    xr = dequantize_blocks(q, s, qc, orig_len=2048)
    err = np.abs(np.asarray(xr) - np.asarray(x))
    bound = np.asarray(s, np.float32).repeat(256, -1).reshape(err.shape) * 0.5
    assert (err <= bound + 1e-12 * scale).all()


def test_quant_handles_zeros_and_padding():
    qc = QuantConfig(bits=8, block=256)
    x = jnp.zeros((1, 100), jnp.float32)  # shorter than a block
    q, s = quantize_blocks(x, qc)
    xr = dequantize_blocks(q, s, qc, orig_len=100)
    assert np.allclose(np.asarray(xr), 0.0)


def test_compressed_psum_multidevice(multidev):
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import compressed_psum, QuantConfig
mesh = jax.make_mesh((8,), ('d',))
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(8, 3000)).astype(np.float32))
for bits, tol in ((8, 0.02), (4, 0.25)):
    fn = jax.jit(shard_map(
        lambda v: compressed_psum(v[0], 'd', QuantConfig(bits=bits, block=256))[0][None],
        mesh=mesh, in_specs=P('d', None), out_specs=P('d', None),
        axis_names={'d'}, check=False))
    y = np.asarray(fn(x))
    ref = np.asarray(x).sum(0)
    rel = np.abs(y[0] - ref).max() / np.abs(ref).max()
    assert rel < tol, (bits, rel)
    for i in range(8):
        assert np.allclose(y[i], y[0])   # all devices agree exactly
print('ok')
""")


def test_compressed_psum_int8_wire_visible(multidev):
    """The lowered HLO must carry int8 (u8/s8) collective operands."""
    multidev("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import compressed_psum, QuantConfig
mesh = jax.make_mesh((8,), ('d',))
fn = jax.jit(shard_map(
    lambda v: compressed_psum(v[0], 'd', QuantConfig(bits=8, block=256))[0][None],
    mesh=mesh, in_specs=P('d', None), out_specs=P('d', None),
    axis_names={'d'}, check=False))
txt = fn.lower(jnp.zeros((8, 3000), jnp.float32)).compile().as_text()
coll = [l for l in txt.splitlines() if 'all-to-all' in l or 'all-gather' in l]
int8_coll = [l for l in coll if 's8[' in l or 'u8[' in l]
assert int8_coll, coll[:5]
print('ok')
""")
