import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.compression import (QuantConfig, dequantize_blocks, pack_int4,
                                    quantize_blocks, unpack_int4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 4096))
def test_int4_pack_roundtrip(seed, n):
    n = n * 2  # even
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-7, 8, n), jnp.int8)
    assert (unpack_int4(pack_int4(q)) == q).all()


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e4])
def test_quant_error_bound(bits, scale):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 2048)).astype(np.float32)) * scale
    qc = QuantConfig(bits=bits, block=256)
    q, s = quantize_blocks(x, qc)
    xr = dequantize_blocks(q, s, qc, orig_len=2048)
    err = np.abs(np.asarray(xr) - np.asarray(x))
    bound = np.asarray(s, np.float32).repeat(256, -1).reshape(err.shape) * 0.5
    assert (err <= bound + 1e-12 * scale).all()


def test_quant_handles_zeros_and_padding():
    qc = QuantConfig(bits=8, block=256)
    x = jnp.zeros((1, 100), jnp.float32)  # shorter than a block
    q, s = quantize_blocks(x, qc)
    xr = dequantize_blocks(q, s, qc, orig_len=100)
    assert np.allclose(np.asarray(xr), 0.0)


def test_compressed_psum_multidevice(multidev):
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import compressed_psum, QuantConfig
mesh = jax.make_mesh((8,), ('d',))
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(8, 3000)).astype(np.float32))
for bits, tol in ((8, 0.02), (4, 0.25)):
    fn = jax.jit(shard_map(
        lambda v: compressed_psum(v[0], 'd', QuantConfig(bits=bits, block=256))[0][None],
        mesh=mesh, in_specs=P('d', None), out_specs=P('d', None),
        axis_names={'d'}, check=False))
    y = np.asarray(fn(x))
    ref = np.asarray(x).sum(0)
    rel = np.abs(y[0] - ref).max() / np.abs(ref).max()
    assert rel < tol, (bits, rel)
    for i in range(8):
        assert np.allclose(y[i], y[0])   # all devices agree exactly
print('ok')
""")


def test_compressed_psum_int8_wire_visible(multidev):
    """The lowered HLO must carry int8 (u8/s8) collective operands."""
    multidev("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import compressed_psum, QuantConfig
mesh = jax.make_mesh((8,), ('d',))
fn = jax.jit(shard_map(
    lambda v: compressed_psum(v[0], 'd', QuantConfig(bits=8, block=256))[0][None],
    mesh=mesh, in_specs=P('d', None), out_specs=P('d', None),
    axis_names={'d'}, check=False))
txt = fn.lower(jnp.zeros((8, 3000), jnp.float32)).compile().as_text()
coll = [l for l in txt.splitlines() if 'all-to-all' in l or 'all-gather' in l]
int8_coll = [l for l in coll if 's8[' in l or 'u8[' in l]
assert int8_coll, coll[:5]
print('ok')
""")


@pytest.mark.parametrize("orig_len", [4095, 4093])
def test_int4_odd_length_pad_roundtrip(orig_len):
    """int4 wire encode with an odd (non-block-multiple) length: the block
    padding plus nibble packing must round-trip back to |err| <= Delta/2 on
    exactly the original elements (DESIGN.md §2)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1, orig_len)).astype(np.float32))
    qc = QuantConfig(bits=4, block=256)
    q, s = quantize_blocks(x, qc)
    # the padded symbol stream is what travels: pack -> unpack -> dequantize
    q_wire = unpack_int4(pack_int4(q))
    assert (q_wire == q).all()
    xr = dequantize_blocks(q_wire, s, qc, orig_len=orig_len)
    assert xr.shape == (1, orig_len)
    err = np.abs(np.asarray(xr) - np.asarray(x))
    bound = np.asarray(s, np.float32).repeat(256, -1)[:, :orig_len] * 0.5
    assert (err <= bound + 1e-12).all()


def test_compressed_psum_int4_wire_visible(multidev):
    """DESIGN.md §2 claims s8/u8 collective operands for the *int4* wire
    too (nibbles packed into uint8); lower at an odd per-chunk length so
    the pack/pad path is the one being compiled."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import compressed_psum, QuantConfig
mesh = jax.make_mesh((8,), ('d',))
qc = QuantConfig(bits=4, block=256)
fn = jax.jit(shard_map(
    lambda v: compressed_psum(v[0], 'd', qc)[0][None],
    mesh=mesh, in_specs=P('d', None), out_specs=P('d', None),
    axis_names={'d'}, check=False))
n = 2999  # odd, not a multiple of the 8*256*2 chunking quantum
txt = fn.lower(jnp.zeros((8, n), jnp.float32)).compile().as_text()
coll = [l for l in txt.splitlines() if 'all-to-all' in l or 'all-gather' in l]
int8_coll = [l for l in coll if 's8[' in l or 'u8[' in l]
assert int8_coll, coll[:5]
# the only non-integer collectives are the per-block scale side channels
# (<= chunk/block elements each; XLA CPU widens their bf16 to f32) — no
# full-chunk-width float payload may appear on the wire
import re
for l in coll:
    if not (' all-to-all(' in l or ' all-gather(' in l):
        continue  # a fusion consuming a collective result, not wire
    for dt, dims in re.findall(r'(f32|bf16)\\[([0-9,]+)\\]', l):
        size = 1
        for d in dims.split(','):
            size *= int(d)
        assert size <= 8 * 8 * 2, (size, l)  # devices^2 x scale blocks
# and the lowered program still sums correctly (quantization error only)
rng = np.random.default_rng(3)
x = jnp.asarray(rng.normal(size=(8, n)).astype(np.float32))
y = np.asarray(fn(x))
ref = np.asarray(x).sum(0)
rel = np.abs(y[0] - ref).max() / np.abs(ref).max()
assert rel < 0.25, rel
print('ok')
""")
