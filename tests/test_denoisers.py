import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.denoisers import BernoulliGauss, eta, make_mmse_interp, mmse


PRIOR = BernoulliGauss(eps=0.1, mu_s=0.0, sigma_s=1.0)


def test_eta_shrinks_toward_zero():
    f = np.linspace(-5, 5, 101)
    out = eta(f, 0.5, PRIOR, xp=np)
    assert np.all(np.abs(out) <= np.abs(f) + 1e-12)
    # odd symmetry for mu_s = 0
    np.testing.assert_allclose(out, -out[::-1], atol=1e-12)


def test_eta_limits():
    f = np.asarray([0.001, 3.0])
    # tiny noise: eta(f) ~ f for f in the slab, ~0 near the spike
    out = eta(f, 1e-6, PRIOR, xp=np)
    assert abs(out[1] - 3.0) < 1e-3
    # huge noise: eta -> prior mean (0)
    out = eta(f, 1e6, PRIOR, xp=np)
    assert np.all(np.abs(out) < 1e-3)


def test_mmse_bounds_and_monotonicity():
    v = np.geomspace(1e-6, 1e3, 50)
    m = mmse(v, PRIOR)
    # MMSE is bounded by the prior variance and by the channel-linear bound
    assert np.all(m <= PRIOR.second_moment + 1e-9)
    assert np.all(m <= v + 1e-9)
    assert np.all(np.diff(m) >= -1e-12)  # nondecreasing in noise


def test_mmse_interp_accuracy():
    interp = make_mmse_interp(PRIOR)
    v = np.geomspace(1e-5, 10, 23)
    exact = mmse(v, PRIOR)
    approx = interp(v)
    np.testing.assert_allclose(approx, exact, rtol=5e-3)


@settings(max_examples=25, deadline=None)
@given(eps=st.floats(0.01, 0.9), sigma2=st.floats(1e-4, 1e2),
       mu=st.floats(-1.0, 1.0))
def test_eta_is_posterior_mean_bounded(eps, sigma2, mu):
    prior = BernoulliGauss(eps=eps, mu_s=mu, sigma_s=1.0)
    f = np.linspace(-10, 10, 41)
    out = eta(f, sigma2, prior, xp=np)
    assert np.all(np.isfinite(out))
    # posterior mean lies between 0 (spike) and the slab posterior mean
    slab = (mu * sigma2 + f * 1.0) / (1.0 + sigma2)
    lo = np.minimum(0.0, slab) - 1e-9
    hi = np.maximum(0.0, slab) + 1e-9
    assert np.all(out >= lo) and np.all(out <= hi)


@settings(max_examples=10, deadline=None)
@given(sigma2=st.floats(1e-3, 10.0))
def test_mmse_quadrature_stable(sigma2):
    a = mmse(np.asarray([sigma2]), PRIOR, n_nodes=2001)[0]
    b = mmse(np.asarray([sigma2]), PRIOR, n_nodes=6001)[0]
    assert abs(a - b) / max(a, 1e-12) < 1e-2
