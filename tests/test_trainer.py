"""End-to-end training-loop tests on a reduced model (single CPU device)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainStepConfig
from repro.runtime import Trainer, TrainerConfig

SHAPE = ShapeSpec("tiny", seq_len=32, global_batch=4, kind="train")


def _trainer(tmp_path, **kw):
    cfg = get_config("granite-3-8b").smoke_config()
    mesh = make_host_mesh(model=1)
    tcfg = TrainerConfig(
        total_steps=kw.pop("total_steps", 12), ckpt_every=5,
        ckpt_dir=str(tmp_path), log_every=0,
        step_cfg=TrainStepConfig(microbatches=2, moe_groups=1),
        **kw)
    return Trainer(cfg, SHAPE, mesh, tcfg)


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, total_steps=25)
    _, _, hist = tr.run(resume=False)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_preemption_resume_deterministic(tmp_path):
    """Kill at step 8, resume -> same final loss as an uninterrupted run."""
    tr_full = _trainer(tmp_path / "full", total_steps=12)
    _, _, hist_full = tr_full.run(resume=False)

    tr_a = _trainer(tmp_path / "resumed", total_steps=12, fail_at_step=8)
    with pytest.raises(RuntimeError, match="simulated preemption"):
        tr_a.run(resume=False)
    tr_b = _trainer(tmp_path / "resumed", total_steps=12)
    _, _, hist_b = tr_b.run(resume=True)
    # resumed run restarts from the step-5 checkpoint
    assert hist_b[0]["step"] == 5
    full = {h["step"]: h["loss"] for h in hist_full}
    for h in hist_b:
        assert abs(h["loss"] - full[h["step"]]) < 2e-2, h
