"""Fault-tolerance tests (DESIGN.md §13): host state machine, typed
error frames, socket timeouts, frame/codec fuzz, failover with
bit-identical replay (the chaos gate), hedging, and the shed ladder."""
import dataclasses
import socket
import struct
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.denoisers import BernoulliGauss
from repro.serving import (BackendError, BackendUnavailable, BucketPolicy,
                           ChaosBackend, ChaosProxy, ClusterRouter,
                           ClusterService, CodecError, FaultPlan, FaultSpec,
                           FrameError, HostInfo, Overloaded,
                           RemoteRequestError, RouterPolicy, ShedLadder,
                           SolveRequest, SolveService, decode_request,
                           encode_request, routing_key)
from repro.serving.frontend import (BackendServer, LocalBackend, TcpBackend,
                                    _unpack_results)
from repro.serving.wire import recv_frame, send_frame

POL = BucketPolicy(max_batch=8, n_quantum=64, mp_quantum=8)


def make_reqs(n_req: int, n: int = 128, m: int = 64, p: int = 4,
              t: int = 8, seed: int = 0):
    import jax

    from repro.core.amp import sample_problem
    from repro.core.state_evolution import CSProblem

    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    deltas = np.full(t, 0.05, np.float32)
    deltas[0] = np.inf
    reqs = []
    for i in range(n_req):
        _, a, y = sample_problem(jax.random.PRNGKey(seed + i), n, m, prior,
                                 prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=p,
                                 n_iter=t, policy="fixed", deltas=deltas))
    return prior, reqs


def local_host(hid: str) -> LocalBackend:
    return LocalBackend(hid, SolveService(policy=POL,
                                          rate_accounting=False))


# ---------------------------------------------------------------------------
# host state machine (router units — no jax, no sockets)
# ---------------------------------------------------------------------------

def three_host_router(**kw):
    pol = RouterPolicy(**kw)
    return ClusterRouter(
        [HostInfo("a"), HostInfo("b"), HostInfo("c")], pol), pol


def any_key():
    _, reqs = make_reqs(1)
    return routing_key(reqs[0], POL)


def test_router_state_machine_transitions():
    r, _ = three_host_router()
    assert r.host_states() == {"a": "healthy", "b": "healthy",
                               "c": "healthy"}
    r.mark_suspect("a")
    assert r.host_state("a") == "suspect"
    r.mark_healthy("a")
    assert r.host_state("a") == "healthy"
    r.mark_dead("a")
    assert r.host_state("a") == "dead"
    r.mark_suspect("a")                      # dead doesn't regress
    assert r.host_state("a") == "dead"
    r.mark_healthy("a")                      # explicit revival works
    assert r.host_state("a") == "healthy"
    r.drain("b")
    assert r.host_state("b") == "draining"


def test_router_dead_host_evicted_and_replicas_refill():
    r, _ = three_host_router(min_replicas=2)
    key = any_key()
    r.route(key, 1.0)
    assert set(r.replicas(key)) == {"a", "b"}
    r.mark_dead("a")
    assert "a" not in r.replicas(key)        # evicted from the set
    assert r.stats()["outstanding"]["a"] == 0.0
    picks = {r.route(key, 1.0) for _ in range(4)}
    assert "a" not in picks                  # never routed to
    assert "c" in r.replicas(key)            # refilled from survivors


def test_router_all_dead_sheds():
    r, _ = three_host_router()
    key = any_key()
    for hid in ("a", "b", "c"):
        r.mark_dead(hid)
    with pytest.raises(Overloaded):
        r.route(key, 1.0)


def test_router_suspect_loses_ties_but_still_routes():
    r, _ = three_host_router(min_replicas=3)
    key = any_key()
    r.mark_suspect("a")
    assert r.route(key, 1.0) in ("b", "c")   # tie goes to the healthy
    r.mark_dead("b")
    r.mark_dead("c")
    assert r.route(key, 1.0) == "a"          # suspect is still capacity


def test_router_avoid_falls_back_to_avoided_live_host():
    r, _ = three_host_router(min_replicas=1)
    key = any_key()
    first = r.route(key, 1.0)
    # every live host avoided: routing there anyway beats shedding
    assert r.route(key, 1.0, avoid=frozenset({"a", "b", "c"})) in (
        "a", "b", "c")
    assert first in ("a", "b", "c")


# ---------------------------------------------------------------------------
# fault plan determinism
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_validated():
    assert FaultPlan.random(7) == FaultPlan.random(7)
    assert FaultPlan.random(7) != FaultPlan.random(8)
    plan = FaultPlan.kill_at(3, ops=("submit",))
    assert plan.fault_for("submit", 3).kind == "kill"
    assert plan.fault_for("poll", 3) is None
    assert plan.fault_for("submit", 2) is None
    with pytest.raises(ValueError):
        FaultSpec("melt", 1)
    with pytest.raises(ValueError):
        FaultSpec("kill", 0)


def test_chaos_backend_kill_error_freeze():
    inner = local_host("h")
    plan = FaultPlan(faults=(FaultSpec("error", 1),
                             FaultSpec("freeze", 2, duration_s=0.0),
                             FaultSpec("kill", 3)))
    naps = []
    cb = ChaosBackend(inner, plan, sleep=naps.append)
    assert cb.host_id == "h" and cb.n_devices >= 1
    with pytest.raises(RemoteRequestError):
        cb.ping()                            # call 1: transient error
    with pytest.raises(BackendUnavailable):
        cb.ping()                            # call 2: freeze -> timeout
    with pytest.raises(BackendUnavailable):
        cb.ping()                            # call 3: killed
    with pytest.raises(BackendUnavailable):
        cb.poll()                            # dead stays dead, any op
    cb.revive()
    assert cb.ping() is True
    assert [k for _, _, k in cb.faults_fired] == ["error", "freeze", "kill"]


# ---------------------------------------------------------------------------
# frame + codec fuzz (satellite: corrupt bytes must raise typed errors,
# never hang or half-deserialize)
# ---------------------------------------------------------------------------

def _frame_roundtrip(payload: bytes) -> bytes:
    a, b = socket.socketpair()
    try:
        send_frame(a, b"R", payload)
        op, body = recv_frame(b)
        assert op == b"R"
        return body
    finally:
        a.close()
        b.close()


def test_frame_roundtrip_and_limits():
    assert _frame_roundtrip(b"") == b""
    assert _frame_roundtrip(b"x" * 70000) == b"x" * 70000   # > one recv
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<I", 0))            # opless empty frame
        with pytest.raises(FrameError):
            recv_frame(b)
    finally:
        a.close()
        b.close()
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<I", (1 << 30) + 1))  # absurd length claim
        with pytest.raises(FrameError):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_frame_truncated_stream_raises_connection_error():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("<I", 100) + b"S" + b"only-ten")
        a.close()                                  # die mid-frame
        with pytest.raises(ConnectionError):
            recv_frame(b)
    finally:
        b.close()


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_codec_fuzz_truncate_corrupt_oversize(data):
    """Any mutation of a valid request frame either decodes cleanly or
    raises ``CodecError`` — nothing else escapes, nothing hangs."""
    rng = np.random.default_rng(0)
    req = SolveRequest(y=rng.standard_normal(8).astype(np.float32),
                       a=rng.standard_normal((8, 16)).astype(np.float32),
                       prior=BernoulliGauss(eps=0.1), n_proc=2, n_iter=3)
    buf = bytearray(encode_request(req))
    mode = data.draw(st.sampled_from(["truncate", "flip", "grow"]))
    if mode == "truncate":
        buf = buf[:data.draw(st.integers(0, len(buf) - 1))]
    elif mode == "flip":
        i = data.draw(st.integers(0, min(400, len(buf) - 1)))
        buf[i] ^= data.draw(st.integers(1, 255))
    else:
        buf += bytes(data.draw(st.integers(1, 64)))
    try:
        decode_request(bytes(buf))
    except CodecError:
        pass


@settings(max_examples=40, deadline=None)
@given(st.binary(max_size=64), st.integers(0, 2 ** 32 - 1))
def test_result_list_fuzz(tail, count):
    """Nested result-list bodies with lying counts/lengths raise
    ``CodecError`` (the TcpBackend reply path), never struct/index
    crashes."""
    body = struct.pack("<I", count) + tail
    try:
        _unpack_results(body)
    except CodecError:
        pass


# ---------------------------------------------------------------------------
# TCP: dead peers, timeouts, typed remote errors (satellites 1 + 2)
# ---------------------------------------------------------------------------

def test_tcp_connect_refused_is_backend_unavailable():
    s = socket.create_server(("127.0.0.1", 0))
    addr = s.getsockname()
    s.close()                                     # nobody listening now
    t0 = time.monotonic()
    with pytest.raises(BackendUnavailable):
        TcpBackend(addr, "ghost", connect_timeout_s=2.0)
    assert time.monotonic() - t0 < 5.0


def test_tcp_dead_peer_times_out_within_budget():
    """A peer that accepts and then stalls mid-reply must fail the call
    within the configured recv timeout — not hang (the ISSUE 10
    acceptance criterion on dead peers)."""
    server = BackendServer(local_host("h"))
    server.start()
    proxy = ChaosProxy((server.host, server.port)).start()
    try:
        tcp = TcpBackend(proxy.address, "h", connect_timeout_s=2.0,
                         recv_timeout_s=0.5)
        assert tcp.ping()                         # healthy through proxy
        proxy.trip("stall")
        t0 = time.monotonic()
        with pytest.raises(BackendUnavailable, match="timed out"):
            tcp.ping()
        assert time.monotonic() - t0 < 3.0        # ~recv_timeout, not inf
        tcp.close()
    finally:
        proxy.stop()
        server.stop()


def test_tcp_severed_connection_is_backend_unavailable():
    server = BackendServer(local_host("h"))
    server.start()
    proxy = ChaosProxy((server.host, server.port)).start()
    try:
        tcp = TcpBackend(proxy.address, "h", recv_timeout_s=2.0)
        assert tcp.ping()
        proxy.trip("sever")
        with pytest.raises(BackendUnavailable):
            tcp.ping()
        tcp.close()
    finally:
        proxy.stop()
        server.stop()


def test_tcp_remote_error_carries_traceback_and_connection_survives():
    """A server-side per-request failure comes back as a typed
    ``RemoteRequestError`` with the remote traceback attached, and the
    *same connection* keeps serving — one bad request must not look
    like a dead host."""
    server = BackendServer(local_host("h"))
    server.start()
    try:
        from repro.serving import PrewarmSpec
        tcp = TcpBackend((server.host, server.port), "h")
        bad = PrewarmSpec(n=13, m=7, n_proc=4, n_iter=8, policy="fixed",
                          prior=BernoulliGauss(eps=0.1))
        with pytest.raises(RemoteRequestError) as ei:
            tcp.prewarm([bad])                    # unbucketable shapes
        assert ei.value.host_id == "h"
        assert "Traceback" in ei.value.remote_traceback
        assert isinstance(ei.value, BackendError)
        assert not isinstance(ei.value, BackendUnavailable)
        assert tcp.ping()                         # connection still live
        _, reqs = make_reqs(1, seed=77)
        assert tcp.submit(reqs[0]) == 0           # and still serves
        assert len(tcp.flush()) == 1
        tcp.shutdown_server()
    finally:
        server.stop()


def test_tcp_kill_server_op_stops_listener():
    server = BackendServer(local_host("h"))
    server.start()
    try:
        tcp = TcpBackend((server.host, server.port), "h")
        tcp.kill_server()                         # chaos X op: abrupt death
        deadline = time.monotonic() + 5.0
        while server._thread.is_alive() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not server._thread.is_alive()
        with pytest.raises(BackendUnavailable):
            TcpBackend((server.host, server.port), "h",
                       connect_timeout_s=1.0)
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# the chaos gate: kill one of two hosts mid-stream (ISSUE 10 acceptance)
# ---------------------------------------------------------------------------

def chaos_cluster(plan: FaultPlan, **rp_kw):
    rp = dict(min_replicas=2, suspect_after=1, dead_after=2,
              retry_limit=2, retry_backoff_s=0.0)
    rp.update(rp_kw)
    return ClusterService(
        backends=[local_host("host0"),
                  ChaosBackend(local_host("host1"), plan)],
        policy=POL, router_policy=RouterPolicy(**rp))


def test_chaos_kill_one_host_zero_loss_bit_identical():
    """Kill host1 mid-stream with requests stranded in its open batch:
    every admitted request completes (zero lost), the replayed results
    are bit-identical to a single-host run of the same stream, the dead
    host is evicted, and recovery latency is recorded."""
    prior, reqs = make_reqs(16)
    ref = SolveService(policy=POL, rate_accounting=False)
    base = ref.solve(reqs)

    # host1's calls: ping is never driven here, so calls are submits —
    # kill on its 5th call leaves 4 requests stranded in an open batch
    cl = chaos_cluster(FaultPlan.kill_at(5))
    got = sorted(cl.solve(reqs), key=lambda r: r.request_id)

    assert len(got) == len(reqs)                  # zero lost
    st_ = cl.stats()
    assert st_["lost"] == 0
    assert st_["failovers"] == 1
    assert st_["retries"] > 0
    assert st_["host_states"]["host1"] == "dead"
    assert st_["recovery"] != {} and st_["recovery"]["count"] >= 1
    assert st_["recovery"]["p95_ms"] > 0.0
    # bit-identical replay: same request template -> same padded bucket
    # program -> same bits, regardless of which host ran it
    for c, b in zip(got, base):
        assert c.request_id == b.request_id
        np.testing.assert_array_equal(np.asarray(c.x), np.asarray(b.x))
        np.testing.assert_array_equal(np.asarray(c.sigma2_hat),
                                      np.asarray(b.sigma2_hat))
    # the fault-tolerance metrics surface in the registry snapshot
    names = {m["name"] for m in cl.metrics()["metrics"]}
    assert {"amp_failover_total", "amp_retry_total",
            "amp_lost_requests_total", "amp_host_state",
            "amp_recovery_seconds"} <= names
    cl.close()


def test_chaos_kill_during_flush_recovers():
    """Death during the flush (dispatch) phase, not submit: stranded
    whole batches replay on the survivor and the flush still returns
    everything."""
    prior, reqs = make_reqs(16)
    # host1 call pattern in solve(): 8 submits land (calls 1-8), then
    # flush rounds start — kill on call 9 strands a full batch of 8
    cl = chaos_cluster(FaultPlan.kill_at(9), dead_after=1)
    got = cl.solve(reqs)
    assert len(got) == len(reqs)
    st_ = cl.stats()
    assert st_["lost"] == 0 and st_["failovers"] == 1
    assert st_["host_states"]["host1"] == "dead"
    cl.close()


def test_chaos_all_hosts_dead_raises_not_hangs():
    _, reqs = make_reqs(4)
    cl = ClusterService(
        backends=[ChaosBackend(local_host("host0"), FaultPlan.kill_at(1)),
                  ChaosBackend(local_host("host1"), FaultPlan.kill_at(1))],
        policy=POL,
        router_policy=RouterPolicy(min_replicas=2, suspect_after=1,
                                   dead_after=1, retry_limit=1,
                                   retry_backoff_s=0.0))
    with pytest.raises((BackendUnavailable, Overloaded)):
        cl.solve(reqs)
    assert set(cl.stats()["host_states"].values()) == {"dead"}
    cl.close()


def test_check_health_walks_suspect_to_dead_without_traffic():
    """The heartbeat alone (no requests in flight) detects a dead host
    within ``dead_after`` probe rounds, and a healthy probe heals a
    suspect."""
    cl = chaos_cluster(FaultPlan.kill_at(1, ops=("ping",)),
                       suspect_after=1, dead_after=3)
    assert cl.check_health()["host1"] == "suspect"      # probe 1 fails
    assert cl.check_health()["host1"] == "suspect"      # probe 2 fails
    assert cl.check_health()["host1"] == "dead"         # probe 3: evicted
    assert cl.check_health()["host0"] == "healthy"
    assert cl.stats()["failovers"] == 1
    cl.close()


def test_check_health_revives_dead_host():
    cb = ChaosBackend(local_host("host1"), FaultPlan.kill_at(1))
    cl = ClusterService(
        backends=[local_host("host0"), cb], policy=POL,
        router_policy=RouterPolicy(min_replicas=2, suspect_after=1,
                                   dead_after=1, retry_backoff_s=0.0))
    assert cl.check_health()["host1"] == "dead"
    cb.revive()
    assert cl.check_health()["host1"] == "healthy"
    _, reqs = make_reqs(2, seed=30)
    assert len(cl.solve(reqs)) == 2               # takes traffic again
    cl.close()


def test_hedge_duplicates_tail_and_dedupes():
    """With hedging armed, an in-flight request stuck past the p99
    budget is duplicated to the other host; exactly one result comes
    back and the loser is absorbed as a zombie (cost returned, nothing
    delivered twice)."""
    _, reqs = make_reqs(1, seed=9)
    cl = ClusterService(
        backends=[local_host("host0"), local_host("host1")], policy=POL,
        router_policy=RouterPolicy(min_replicas=2, hedge_p99_mult=2.0))
    key = routing_key(reqs[0], POL)
    from collections import deque
    cl._lat[key] = deque([0.001] * 8)             # latency history: p99≈1ms
    gid = cl.submit(reqs[0])
    (hk, fl), = cl._inflight.items()
    fl.t_submit -= 10.0                           # stuck way past budget
    cl.poll()                                     # hedge fires here
    assert cl.hedges == 1
    assert len(cl._inflight) == 2                 # original + duplicate
    assert {h for h, _ in cl._inflight} == {"host0", "host1"}
    got = cl.flush()
    assert [r.request_id for r in got] == [gid]   # exactly one delivery
    assert cl._inflight == {} and cl._zombies == {}
    assert cl.stats()["router"]["outstanding"] == {"host0": 0.0,
                                                   "host1": 0.0}
    cl.close()


# ---------------------------------------------------------------------------
# graceful degradation (tentpole part 3)
# ---------------------------------------------------------------------------

def test_shed_ladder_escalates_and_relaxes():
    t = [0.0]
    lad = ShedLadder(window_s=1.0, up_after=3, clock=lambda: t[0])
    for _ in range(3):
        lad.record_shed()
    assert lad.level == 1
    for _ in range(3):
        lad.record_shed()
    assert lad.level == 2
    t[0] = 0.5
    assert lad.relax() == 2                       # calm window not over
    t[0] = 1.6
    assert lad.relax() == 1                       # one step per window
    assert lad.relax() == 1
    t[0] = 3.0
    assert lad.relax() == 0
    # sheds outside the window never escalate
    for i in range(10):
        t[0] = 10.0 + 2.0 * i
        lad.record_shed()
    assert lad.level == 0


def test_shed_ladder_level1_strips_extras_level2_quotes_mse():
    _, reqs = make_reqs(1)
    req = dataclasses.replace(reqs[0], measure_wire=False)
    lad = ShedLadder()
    lad.level = 1
    r1, q1 = lad.apply(dataclasses.replace(req, measure_wire=True))
    assert r1.measure_wire is False and q1["level"] == 1
    same, qn = lad.apply(req)                     # nothing to strip
    assert qn is None and same is req
    lad.level = 2
    r2, q2 = lad.apply(req)
    assert r2.n_iter == (req.n_iter + 1) // 2
    assert len(r2.deltas) == r2.n_iter            # schedule cut with it
    # the quote prices the cut via SE: fewer iterations, no lower MSE
    assert q2["mse_degraded"] >= q2["mse_full"] > 0.0
    assert q2["n_iter_full"] == req.n_iter


def test_shed_ladder_degraded_requests_still_solve():
    """End to end at ladder level 2: overload escalation degrades later
    requests (counted + quoted) and they still complete."""
    _, reqs = make_reqs(6)
    key = routing_key(reqs[0], POL)
    from repro.serving import shape_cost
    cl = ClusterService(
        n_hosts=1, policy=POL, rate_accounting=False,
        router_policy=RouterPolicy(min_replicas=1, shed_ladder=True,
                                   max_outstanding=2.5 * shape_cost(key)))
    cl._ladder.level = 2                          # as if storms escalated it
    done = 0
    for r in reqs[:2]:
        try:
            cl.submit(r)
            done += 1
        except Overloaded:
            pass
    got = cl.flush()
    assert len(got) == done == 2
    st_ = cl.stats()
    assert st_["degraded"] == 2
    assert cl.shed_quotes[0]["mse_degraded"] >= cl.shed_quotes[0]["mse_full"]
    assert st_["shed_ladder_level"] == 2
    assert {r.request_id for r in got} == set(range(done))
    cl.close()
