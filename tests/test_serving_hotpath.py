"""Serving hot-path tests (ISSUE 6 acceptance): AOT prewarm -> zero
steady-state recompiles, operand-cache hit/miss/evict semantics, buffer
donation not breaking finalize, and the singleton fast path parity-pinned
against the batched path."""
import jax
import numpy as np
import pytest

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                               FixedSchedule)
from repro.core.state_evolution import CSProblem
from repro.serving import (BucketPolicy, OperandCache, PrewarmSpec,
                           SolveRequest, SolveService, batch_width_ladder,
                           fingerprint)

# N/M = 3 stays below col_aspect: these tests pin the *row* hot path
N, M, P, T = 192, 64, 4, 4
POLICY = BucketPolicy(max_batch=4, n_quantum=64, mp_quantum=8)


@pytest.fixture(scope="module")
def inst():
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=N, m=M, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), N, M, prior,
                              prob.sigma_e2)
    return prior, np.asarray(a), np.asarray(y), np.asarray(s0)


def _req(a, y, prior, policy="fixed", **kw):
    if policy == "fixed" and "deltas" not in kw:
        kw["deltas"] = np.full(T, 0.05, np.float32)
    return SolveRequest(y=y, a=a, prior=prior, n_proc=P, n_iter=T,
                        policy=policy, **kw)


# ---------------------------------------------------------------------------
# units: cache primitives + width ladder
# ---------------------------------------------------------------------------

def test_fingerprint_tracks_content():
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    f1 = fingerprint(a)
    assert f1 == fingerprint(a.copy())          # content, not object id
    a[1, 2] += 1.0                              # in-place mutation
    assert fingerprint(a) != f1
    # shape/dtype are part of the identity
    assert fingerprint(a.reshape(4, 3)) != fingerprint(a)
    # non-contiguous views hash their logical content
    b = np.arange(24, dtype=np.float32).reshape(4, 6)
    assert fingerprint(b[:, ::2]) == fingerprint(np.ascontiguousarray(
        b[:, ::2]))


def test_operand_cache_lru_eviction():
    import jax.numpy as jnp
    cache = OperandCache(max_bytes=2 * 400)     # fits two (100,) f32 entries
    mk = lambda i: (lambda: jnp.full(100, float(i)))
    cache.get("a", mk(1))
    cache.get("b", mk(2))
    cache.get("a", mk(1))                       # refresh a's recency
    assert (cache.hits, cache.misses, len(cache)) == (1, 2, 2)
    cache.get("c", mk(3))                       # evicts b (LRU), not a
    assert cache.evictions == 1 and len(cache) == 2
    cache.get("a", mk(1))
    assert cache.hits == 2                      # a survived
    cache.get("b", mk(2))                       # b was evicted: a rebuild
    assert cache.misses == 4
    # an over-budget entry is admitted (newest always kept) and evicts rest
    big = OperandCache(max_bytes=100)
    big.get("x", lambda: jnp.zeros(1000))
    assert len(big) == 1 and big.nbytes == 4000
    stats = big.stats()
    assert stats["entries"] == 1 and stats["max_bytes"] == 100


def test_batch_width_ladder():
    assert batch_width_ladder(BucketPolicy(max_batch=128)) == \
        (1, 2, 4, 8, 16, 32, 64, 128)
    assert batch_width_ladder(POLICY) == (1, 2, 4)
    # data placement: widths round to device multiples
    assert batch_width_ladder(BucketPolicy(max_batch=128), 8) == \
        (8, 16, 32, 64, 128)
    assert batch_width_ladder(BucketPolicy(max_batch=8), 8) == (8,)


# ---------------------------------------------------------------------------
# steady state: prewarm -> zero new compiles, repeated A -> cache hits
# ---------------------------------------------------------------------------

def test_zero_recompiles_after_prewarm(inst):
    """A steady-state stream over a prewarmed bucket menu triggers zero
    XLA compiles (engine compile counters stay flat) and the operand
    cache serves every repeated-A slot."""
    prior, a, y, _ = inst
    svc = SolveService(policy=POLICY)
    report = svc.prewarm([PrewarmSpec(n=N, m=M, n_proc=P, n_iter=T,
                                      policy="fixed", prior=prior)])
    assert report["programs"] > 0
    assert svc.stats()["prewarm"] == report
    c0 = svc.compile_count()
    assert c0 == report["programs"]

    # mixed widths over one bucket: a full group, a straggler pair, and a
    # lone request (the singleton program was prewarmed too); lossless
    # and fixed share the has_bt=False programs
    list(svc.stream([_req(a, y, prior) for _ in range(4)]))
    svc.solve([_req(a, y, prior), _req(a, y, prior, policy="lossless")])
    svc.solve([_req(a, y, prior)])
    stats = svc.stats()
    assert svc.compile_count() == c0, stats["compiles"]
    assert stats["operand_cache"]["hits"] > 0
    assert stats["singleton_dispatches"] == 1
    # demand counters saw every admitted request
    assert sum(stats["bucket_demand"].values()) == 7


def test_prewarm_background_thread(inst):
    prior, a, y, _ = inst
    svc = SolveService(policy=POLICY)
    th = svc.prewarm([PrewarmSpec(n=N, m=M, n_proc=P, n_iter=T,
                                  policy="fixed", prior=prior,
                                  batch_widths=(2,))],
                     background=True)
    th.join(timeout=120)
    assert not th.is_alive()
    c0 = svc.compile_count()
    svc.solve([_req(a, y, prior), _req(a, y, prior)])
    assert svc.compile_count() == c0
    # het width-2 program + the singleton fast-path program
    assert svc.stats()["prewarm"]["programs"] == 2


# ---------------------------------------------------------------------------
# operand cache through the service: hits, mutation misses, eviction
# ---------------------------------------------------------------------------

def test_operand_cache_hit_and_mutation_miss(inst):
    """Repeated A is a hit; in-place mutation of the caller's array is a
    miss that produces the *mutated* problem's solution (no stale hit)."""
    prior, a, y, _ = inst
    svc = SolveService(policy=POLICY, rate_accounting=False)
    a_mut = a.copy()
    r1, = svc.solve([_req(a_mut, y, prior)])
    misses0 = svc.stats()["operand_cache"]["misses"]
    r2, = svc.solve([_req(a_mut, y, prior)])
    st = svc.stats()["operand_cache"]
    assert st["misses"] == misses0 and st["hits"] >= 1
    np.testing.assert_allclose(r1.x, r2.x)

    a_mut[:, : N // 2] = 0.0                    # mutate in place
    r3, = svc.solve([_req(a_mut, y, prior)])
    assert svc.stats()["operand_cache"]["misses"] == misses0 + 1
    # reference solve of the mutated problem: the cache never served the
    # stale operand
    eng = AmpEngine(prior, EngineConfig(n_proc=P, n_iter=T,
                                        collect_symbols=False),
                    EcsqTransport(), FixedSchedule(np.full(T, 0.05)))
    ref = eng.solve(y, a_mut)
    assert float(np.mean((r3.x - ref.x) ** 2)) <= 1e-10
    assert float(np.mean((r3.x - r1.x) ** 2)) > 1e-8


def test_operand_cache_respects_a_id(inst):
    """A caller-managed ``a_id`` replaces the content hash as the cache
    identity (no per-request hashing for registered matrices)."""
    prior, a, y, _ = inst
    svc = SolveService(policy=POLICY, rate_accounting=False)
    svc.solve([_req(a, y, prior, a_id="sensor-0")])
    svc.solve([_req(a, y, prior, a_id="sensor-0")])
    st = svc.stats()["operand_cache"]
    assert st["hits"] >= 1
    assert any(k[1] == "sensor-0" for k in svc._opcache._entries)


def test_lru_eviction_under_small_budget(inst):
    """Two alternating As under a one-entry byte budget thrash by design
    — evictions counted, results stay correct."""
    prior, a, y, _ = inst
    a2 = np.roll(a, 1, axis=1)
    # one padded slice is P*mp*N*4 = 64*192*4 = 48 KiB: budget fits one
    svc = SolveService(policy=POLICY, rate_accounting=False,
                       operand_cache_bytes=64 << 10)
    r1a, = svc.solve([_req(a, y, prior)])
    r2a, = svc.solve([_req(a2, y, prior)])
    r1b, = svc.solve([_req(a, y, prior)])
    st = svc.stats()["operand_cache"]
    assert st["evictions"] >= 1
    assert st["bytes"] <= 64 << 10
    np.testing.assert_allclose(r1a.x, r1b.x)
    assert float(np.mean((r1a.x - r2a.x) ** 2)) > 1e-8


def test_cache_disabled_still_serves(inst):
    prior, a, y, _ = inst
    svc = SolveService(policy=POLICY, rate_accounting=False,
                       operand_cache_bytes=0)
    r1, = svc.solve([_req(a, y, prior)])
    assert svc.stats()["operand_cache"] is None
    svc2 = SolveService(policy=POLICY, rate_accounting=False)
    r2, = svc2.solve([_req(a, y, prior)])
    np.testing.assert_allclose(r1.x, r2.x, atol=1e-7)


# ---------------------------------------------------------------------------
# singleton fast path + donation
# ---------------------------------------------------------------------------

def test_singleton_fastpath_parity(inst):
    """A lone row request routes through ``dispatch_single`` and matches
    both the batched het path and the plain engine solve."""
    prior, a, y, _ = inst
    fast = SolveService(policy=POLICY)
    slow = SolveService(policy=POLICY, singleton_fastpath=False)
    rf, = fast.solve([_req(a, y, prior)])
    rs, = slow.solve([_req(a, y, prior)])
    assert fast.stats()["singleton_dispatches"] == 1
    assert slow.stats()["singleton_dispatches"] == 0
    assert float(np.mean((rf.x - rs.x) ** 2)) <= 1e-10
    np.testing.assert_allclose(rf.sigma2_hat, rs.sigma2_hat, rtol=1e-4)
    np.testing.assert_allclose(rf.rates, rs.rates, rtol=1e-6)
    # plain-engine reference: the fast path is that exact program
    eng = AmpEngine(prior, EngineConfig(n_proc=P, n_iter=T,
                                        collect_symbols=False),
                    EcsqTransport(), FixedSchedule(np.full(T, 0.05)))
    ref = eng.solve(y, a)
    np.testing.assert_allclose(rf.x, ref.x)
    # BT stays on the het path (in-graph controller machinery)
    rb, = fast.solve([_req(a, y, prior, policy="bt")])
    assert fast.stats()["singleton_dispatches"] == 1
    assert np.isfinite(rb.total_bits)


def test_donation_smoke(inst):
    """Donated batch operands (the default) are consumed by the engine
    without breaking finalize or invalidating cache-resident shards —
    back-to-back flushes over the same A agree with a non-donating
    service."""
    prior, a, y, _ = inst
    svc = SolveService(policy=POLICY, rate_accounting=False)  # donate=True
    ref = SolveService(policy=POLICY, rate_accounting=False, donate=False,
                       singleton_fastpath=False)
    assert svc._engine(svc._key_for(svc._prepare(
        _req(a, y, prior)))).cfg.donate
    for _ in range(2):                          # reuse across flushes
        r1, r2 = svc.solve([_req(a, y, prior), _req(a, y, prior)])
        np.testing.assert_allclose(r1.x, r2.x)
    q1, q2 = ref.solve([_req(a, y, prior), _req(a, y, prior)])
    np.testing.assert_allclose(r1.x, q1.x, atol=1e-7)
    # the cached device shards survived every donating dispatch
    for val, _nb in svc._opcache._entries.values():
        for leaf in jax.tree_util.tree_leaves(val):
            assert not leaf.is_deleted()
