import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: device count is deliberately NOT forced here — smoke tests run on the
# single real CPU device. Multi-device tests spawn subprocesses with
# XLA_FLAGS=--xla_force_host_platform_device_count=8 (see _multidev helper).
import subprocess

import pytest


def run_multidev(code: str, n_dev: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with n_dev fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"multidev subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def multidev():
    return run_multidev
