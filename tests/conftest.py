import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# hypothesis guard: the property-based tests use hypothesis, which is a dev
# dependency (requirements-dev.txt). When it is absent, install a stub whose
# @given marks the test skipped, so the remaining tests in those modules still
# collect and run instead of erroring at import.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest as _pytest

    def _given(*_a, **_k):
        def deco(fn):
            def _skipper():
                _pytest.skip("hypothesis not installed")
            _skipper.__name__ = fn.__name__
            _skipper.__doc__ = fn.__doc__
            return _skipper
        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = _AnyStrategy().__getattr__
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

# NOTE: device count is deliberately NOT forced here — smoke tests run on the
# single real CPU device. Multi-device tests spawn subprocesses with
# XLA_FLAGS=--xla_force_host_platform_device_count=8 (see _multidev helper).
import subprocess

import pytest


def run_multidev(code: str, n_dev: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with n_dev fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"multidev subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def multidev():
    return run_multidev
