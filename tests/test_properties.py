"""Cross-cutting property tests (system invariants)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# DP optimality (paper Sec. 3.4): no schedule with the same budget beats DP
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dp_ctx():
    from repro.core.denoisers import BernoulliGauss, make_mmse_interp
    from repro.core.rate_alloc import dp_allocate
    from repro.core.rate_distortion import RDModel
    from repro.core.state_evolution import CSProblem
    prob = CSProblem(prior=BernoulliGauss(eps=0.05))
    rd = RDModel(prob.prior)
    mm = make_mmse_interp(prob.prior)
    t, r_total = 8, 16.0
    dp = dp_allocate(prob, 30, t, r_total, rd=rd, mmse_fn=mm)
    return prob, rd, mm, t, r_total, dp


def _run_schedule(prob, rd, mm, rates, p=30):
    sig = prob.sigma0_2
    for rt in rates:
        sq2 = float(rd.distortion_msg(max(rt, 0.0), sig, p))
        sig = prob.sigma_e2 + float(mm(sig + p * sq2)) / prob.kappa
    return sig


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dp_beats_random_schedules(dp_ctx, seed):
    """DP's final variance is minimal among random same-budget schedules
    (on the DP's own rate grid, where its optimality claim lives)."""
    prob, rd, mm, t, r_total, dp = dp_ctx
    rng = np.random.default_rng(seed)
    # random split of the budget on the 0.1-bit grid
    ticks = int(round(r_total / 0.1))
    counts = rng.multinomial(ticks, np.ones(t) / t)
    rates = counts * 0.1
    sig_rand = _run_schedule(prob, rd, mm, rates)
    assert dp.sigma2_d[-1] <= sig_rand * (1 + 1e-9), (rates, sig_rand)


# ---------------------------------------------------------------------------
# head padding mask invariants
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(kv=st.integers(1, 8), g=st.integers(1, 8),
       mult=st.sampled_from([4, 8, 16]))
def test_head_mask_counts(kv, g, mult):
    import dataclasses
    from repro.configs.base import ModelConfig
    from repro.models.layers import head_mask
    h = kv * g
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                      n_heads=h, n_kv_heads=kv, d_head=4, d_ff=8, vocab=64)
    cfgp = cfg.padded_heads(mult)
    assert cfgp.h_eff % mult == 0
    assert cfgp.h_eff % cfgp.kv_eff == 0
    m = head_mask(cfgp)
    if m is None:  # no padding was needed
        assert cfgp.h_eff == h
        return
    m = np.asarray(m)
    # exactly the original number of active heads, correctly grouped
    assert int(m.sum()) == h
    g_eff = cfgp.h_eff // cfgp.kv_eff
    grouped = m.reshape(cfgp.kv_eff, g_eff)
    assert np.all(grouped.sum(axis=1)[:kv] == g)


# ---------------------------------------------------------------------------
# compressed psum properties
# ---------------------------------------------------------------------------

def test_compressed_psum_zero_and_determinism(multidev):
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.compression import compressed_psum, QuantConfig
mesh = jax.make_mesh((4,), ('d',))
fn = jax.jit(shard_map(
    lambda v: compressed_psum(v[0], 'd', QuantConfig(bits=8, block=128))[0][None],
    mesh=mesh, in_specs=P('d', None), out_specs=P('d', None),
    axis_names={'d'}, check=False))
# zeros -> exactly zeros (no bias injected by the scale floor)
z = jnp.zeros((4, 1000), jnp.float32)
assert np.all(np.asarray(fn(z)) == 0.0)
# determinism: same input -> bit-identical output
x = jnp.asarray(np.random.default_rng(7).normal(size=(4, 1000)).astype(np.float32))
a, b = np.asarray(fn(x)), np.asarray(fn(x))
assert np.array_equal(a, b)
# sign symmetry: Q(-x) == -Q(x) for the midtread quantizer
c = np.asarray(fn(-x))
assert np.allclose(a, -c, atol=1e-6)
print('ok')
""", 4)


# ---------------------------------------------------------------------------
# int4/int8 block-quantization round-trip properties (the serving/collective
# wire format: core/compression.py)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([4, 8]), block=st.sampled_from([128, 512]),
       n=st.integers(1, 1500), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3))
def test_block_quantize_roundtrip_properties(bits, block, n, seed, scale):
    """pack/unpack identity, |dequant error| <= Delta_b/2, and the
    quant_noise_var accounting upper-bounds the realized MSE."""
    from repro.core.compression import (QuantConfig, dequantize_blocks,
                                        pack_int4, quant_noise_var,
                                        quantize_blocks, unpack_int4)
    qc = QuantConfig(bits=bits, block=block)
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(2, n)) * scale).astype(np.float32))
    q, s = quantize_blocks(x, qc)

    # (a) symbols bounded by the wire width (no silent overflow)
    assert int(jnp.abs(q).max()) <= qc.qmax

    # (b) int4 wire format: pack/unpack is the identity on symbols
    if bits == 4:
        assert (unpack_int4(pack_int4(q)) == q).all()

    # (c) per-element reconstruction error <= Delta_b/2: the bf16 scale
    # nudge guarantees the max element never clips
    deq = np.asarray(dequantize_blocks(q, s, qc, orig_len=n))
    err = np.abs(deq - np.asarray(x))
    d_elem = np.repeat(np.asarray(s, np.float32), qc.block, axis=-1)[:, :n]
    assert (err <= d_elem / 2 + 1e-6 * float(scale)).all()

    # (d) quant_noise_var = mean(Delta_b^2)/12 upper-bounds the realized
    # MSE up to the uniform-error worst case factor 3 (Delta^2/4 vs /12);
    # measured over the padded layout (q keeps the block padding)
    deq_pad = np.asarray(dequantize_blocks(q, s, qc))
    x_pad = np.zeros_like(deq_pad)
    x_pad[:, :n] = np.asarray(x)
    mse = float(np.mean((deq_pad - x_pad) ** 2))
    assert mse <= 3.0 * float(quant_noise_var(s, qc)) + 1e-12 * scale**2


# ---------------------------------------------------------------------------
# quantized SE monotonicity in the rate (more bits never hurt)
# ---------------------------------------------------------------------------

def test_se_monotone_in_rate():
    from repro.core.denoisers import BernoulliGauss, make_mmse_interp
    from repro.core.rate_distortion import RDModel
    from repro.core.state_evolution import CSProblem
    prob = CSProblem(prior=BernoulliGauss(eps=0.05))
    rd = RDModel(prob.prior)
    mm = make_mmse_interp(prob.prior)
    finals = []
    for rate in (0.5, 1.0, 2.0, 4.0):
        sig = prob.sigma0_2
        for _ in range(8):
            sq2 = float(rd.distortion_msg(rate, sig, 30))
            sig = prob.sigma_e2 + float(mm(sig + 30 * sq2)) / prob.kappa
        finals.append(sig)
    assert all(a >= b - 1e-12 for a, b in zip(finals, finals[1:])), finals
