"""Sharded-vs-emulated engine parity (8 fake devices, subprocess).

ISSUE 3 acceptance: ``AmpEngine.solve_sharded`` pins to ``solve`` — the
exact transport bitwise-close (<=1e-12 MSE difference), quantized
transports within the documented ulp-reassociation envelope (a 1-ulp
matmul difference can flip a round-half-even symbol, so those compare
behaviorally, exactly like ``solve_many``'s documented contract) — and the
processor-sharded het path pins to ``solve_het``.
"""


def test_solve_sharded_matches_solve_exact(multidev):
    """Exact transport: the sharded scan is the emulated scan to float ulp
    (same LC op, same GC tail, psum instead of a leading-axis sum)."""
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, EngineConfig, ExactFusion,
                               PsumFusion)
from repro.core.state_evolution import CSProblem

prior = BernoulliGauss(eps=0.1)
prob = CSProblem(n=2000, m=600, prior=prior)
s0, a, y = sample_problem(jax.random.PRNGKey(1), prob.n, prob.m, prior,
                          prob.sigma_e2)
mesh = make_mesh((8,), ('data',))

# P = 8 (one processor per device) and P = 24 (3 emulated per device)
for p in (8, 24):
    cfg = EngineConfig(n_proc=p, n_iter=10, collect_symbols=False)
    em = AmpEngine(prior, cfg, ExactFusion()).solve(y, a)
    sh = AmpEngine(prior, cfg, PsumFusion(axis='data')).solve_sharded(
        y, a, mesh)
    d = float(np.mean((em.x - sh.x) ** 2))
    assert d <= 1e-12, (p, d)
    np.testing.assert_allclose(sh.sigma2_hat, em.sigma2_hat, rtol=1e-6)
print('ok')
""", 8, timeout=900)


def test_solve_sharded_quantized_envelope(multidev):
    """Quantized transports: per-processor ECSQ across devices tracks the
    emulated solve within the ulp-reassociation envelope, and the
    compressed-wire transport stays near-exact in quality."""
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, CompressedPsumTransport,
                               EcsqTransport, EngineConfig, ExactFusion,
                               FixedSchedule, PsumFusion)
from repro.core.state_evolution import CSProblem

prior = BernoulliGauss(eps=0.1)
prob = CSProblem(n=2000, m=600, prior=prior)
s0, a, y = sample_problem(jax.random.PRNGKey(1), prob.n, prob.m, prior,
                          prob.sigma_e2)
mesh = make_mesh((8,), ('data',))
t = 10
deltas = np.full(t, 0.05, np.float32)
deltas[0] = np.inf
cfg = EngineConfig(n_proc=24, n_iter=t, collect_symbols=False)

em = AmpEngine(prior, cfg, EcsqTransport(), FixedSchedule(deltas)).solve(y, a)
sh = AmpEngine(prior, cfg, PsumFusion(axis='data', local=EcsqTransport()),
               FixedSchedule(deltas)).solve_sharded(y, a, mesh)
# same quantizers, different summation order: trajectory-level agreement
np.testing.assert_allclose(sh.sigma2_hat, em.sigma2_hat, rtol=0.02)
np.testing.assert_allclose(sh.extra_var, em.extra_var, rtol=1e-6)
mse_em = float(em.mse(s0)[-1])
mse_sh = float(np.mean((sh.x - s0) ** 2))
assert abs(mse_sh - mse_em) <= 0.05 * mse_em + 1e-8, (mse_sh, mse_em)

# straggler rescale amplifies the survivors' embedded quantization noise:
# with k of D shards dropped the accounting must report D/(D-k) times the
# no-drop P*delta^2/12 (survivor noise scaled by (D/n_keep)^2)
drop = np.zeros((t, 8), np.float32)
drop[3, :2] = 1.0  # 2 of 8 shards out at iteration 3
shd = AmpEngine(prior, cfg, PsumFusion(axis='data', local=EcsqTransport()),
                FixedSchedule(deltas)).solve_sharded(y, a, mesh,
                                                     drop_sched=drop)
np.testing.assert_allclose(shd.extra_var[3], sh.extra_var[3] * 8.0 / 6.0,
                           rtol=1e-5)
np.testing.assert_allclose(shd.extra_var[4], sh.extra_var[4], rtol=0.5)

# compressed wire: near-exact quality, noise accounting active
ex = AmpEngine(prior, cfg, ExactFusion()).solve(y, a)
cp = AmpEngine(prior, cfg,
               CompressedPsumTransport(axis='data', bits=8,
                                       block=256)).solve_sharded(y, a, mesh)
mse_ex = float(ex.mse(s0)[-1])
mse_cp = float(np.mean((cp.x - s0) ** 2))
assert mse_cp < mse_ex * 1.25, (mse_cp, mse_ex)
assert np.all(cp.extra_var > 0)
print('ok')
""", 8, timeout=900)


def test_solve_sharded_het_matches_solve_het(multidev):
    """Processor-sharded het solve (padded shards, masked columns, traced
    prior, BT tables replicated) == the emulated solve_het instance."""
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, EcsqTransport, EngineConfig,
                               PsumFusion)
from repro.core.state_evolution import CSProblem
from repro.serving import BucketPolicy, SolveRequest
from repro.serving.service import SolveService

prior = BernoulliGauss(eps=0.05)
prob = CSProblem(n=1500, m=400, prior=prior, snr_db=20.0)
s0, a, y = sample_problem(jax.random.PRNGKey(5), prob.n, prob.m, prior,
                          prob.sigma_e2)
mesh = make_mesh((8,), ('data',))

# shard_elems=1 forces processor-sharded placement for this request
svc_proc = SolveService(policy=BucketPolicy(shard_elems=1), mesh=mesh)
svc_loc = SolveService(policy=BucketPolicy())
req = lambda policy: SolveRequest(y=y, a=a, prior=prior, snr_db=20.0,
                                  n_proc=8, n_iter=7, policy=policy)
for policy in ('lossless', 'bt'):
    rp, = svc_proc.solve([req(policy)])
    rl, = svc_loc.solve([req(policy)])
    assert rp.bucket.placement == 'proc' and rl.bucket.placement == 'local'
    d = float(np.mean((rp.x - rl.x) ** 2))
    if policy == 'lossless':
        assert d <= 1e-12, d
        np.testing.assert_allclose(rp.sigma2_hat, rl.sigma2_hat, rtol=1e-5)
    else:
        # BT's cap/bisection branch is discontinuous in sigma2_hat: a
        # 1-ulp plug-in difference may flip one decision, so BT compares
        # behaviorally (final quality), like the quantized transports
        mse_p = float(np.mean((rp.x - s0) ** 2))
        mse_l = float(np.mean((rl.x - s0) ** 2))
        assert mse_p <= 1.3 * mse_l + 1e-8, (mse_p, mse_l)
        assert np.isfinite(rp.total_bits)
print('ok')
""", 8, timeout=900)


def test_solve_sharded_kernel_interpret_matches_emulated(multidev):
    """ISSUE 5 satellite: the sharded (shard_map) path routes through the
    same batched-grid kernel entry point as the emulated path — each
    device launches one kernel over its P/D emulated processors. Pinned
    against the emulated jnp solve for both layouts (exact transports;
    the row pin inherits the <=1e-12 class of the jnp-path pins)."""
    multidev("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.engine import (AmpEngine, ColumnPartition, EngineConfig,
                               ExactFusion, PsumFusion)
from repro.core.state_evolution import CSProblem

prior = BernoulliGauss(eps=0.1)
prob = CSProblem(n=1024, m=256, prior=prior)
s0, a, y = sample_problem(jax.random.PRNGKey(1), prob.n, prob.m, prior,
                          prob.sigma_e2)
mesh = make_mesh((8,), ('data',))

cfg = lambda **kw: EngineConfig(n_proc=8, n_iter=5, collect_symbols=False,
                                use_kernel=True, kernel_interpret=True, **kw)
em = AmpEngine(prior, EngineConfig(n_proc=8, n_iter=5,
                                   collect_symbols=False),
               ExactFusion()).solve(y, a)
sh = AmpEngine(prior, cfg(), PsumFusion(axis='data')).solve_sharded(
    y, a, mesh)
d = float(np.mean((em.x - sh.x) ** 2))
assert d <= 1e-10, d
np.testing.assert_allclose(sh.sigma2_hat, em.sigma2_hat, rtol=1e-5)

lay = ColumnPartition(n_inner=2)
emc = AmpEngine(prior, EngineConfig(n_proc=8, n_iter=5,
                                    collect_symbols=False, layout=lay),
                ExactFusion()).solve(y, a)
shc = AmpEngine(prior, cfg(layout=lay),
                PsumFusion(axis='data')).solve_sharded(y, a, mesh)
dc = float(np.mean((emc.x - shc.x) ** 2))
assert dc <= 1e-10, dc
print('ok')
""", 8, timeout=900)


def test_service_data_parallel_matches_local(multidev):
    """Data-parallel placement: batch-axis sharding must not change any
    request's result (placement is an execution detail, not semantics)."""
    multidev("""
import jax, numpy as np
from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.state_evolution import CSProblem
from repro.launch.mesh import make_serve_mesh
from repro.serving import BucketPolicy, SolveRequest, SolveService

prior = BernoulliGauss(eps=0.1)
prob = CSProblem(n=512, m=128, prior=prior)
reqs = []
for i in range(6):   # 6 real -> padded to 8 (device multiple)
    s0, a, y = sample_problem(jax.random.PRNGKey(i), prob.n, prob.m, prior,
                              prob.sigma_e2)
    t = 4 + (i % 3)  # mixed iteration budgets in one bucket
    reqs.append(SolveRequest(y=y, a=a, prior=prior, n_proc=4, n_iter=t,
                             policy='lossless'))

mesh = make_serve_mesh()
svc_mesh = SolveService(policy=BucketPolicy(max_batch=8), mesh=mesh)
svc_loc = SolveService(policy=BucketPolicy(max_batch=8))
res_m = svc_mesh.solve(reqs)
res_l = svc_loc.solve(reqs)
assert all(r.bucket.placement == 'data' for r in res_m)
for rm, rl in zip(res_m, res_l):
    d = float(np.mean((rm.x - rl.x) ** 2))
    assert d <= 1e-10, d
print('ok')
""", 8, timeout=900)
