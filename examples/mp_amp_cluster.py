"""Cluster-style MP-AMP: the paper's P=30 experiment + the mesh-distributed
solver with compressed psum fusion on 8 (emulated) devices.

Part 1 reproduces a Table-1 column (eps=0.05): BT vs DP rate allocation with
real ECSQ quantizers and empirical-entropy rate accounting.
Part 2 runs the same algorithm as true SPMD over a device mesh, fusing with
int8-transport compressed psum (the TPU-native form of the paper's
compression), including straggler-tolerant partial fusion.

Run:  python examples/mp_amp_cluster.py        (sets its own XLA device count)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.amp import amp_solve, sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.engine import DPSchedule
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve
from repro.core.rate_alloc import BTController, dp_allocate
from repro.core.rate_distortion import RDModel
from repro.core.state_evolution import PAPER_T, CSProblem
from repro.launch.solver import DistributedMPAMP, SolverConfig


def part1_paper_experiment():
    eps = 0.05
    prior = BernoulliGauss(eps=eps)
    prob = CSProblem(prior=prior)
    t = PAPER_T[eps]
    print(f"=== Part 1: paper experiment (eps={eps}, P=30, T={t}) ===")
    rd = RDModel(prior)
    mm = make_mmse_interp(prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m, prior,
                              prob.sigma_e2)
    sdr = lambda mse: 10 * np.log10(prior.second_moment / mse)

    cen = amp_solve(y, a, prior, t, s0=s0)
    print(f"centralized : SDR {sdr(cen.mse[-1]):6.2f} dB, {32*t} bits/elem")

    ctrl = BTController(prob, 30, t, 1.005, 6.0, "ecsq", mmse_fn=mm)
    bt = mp_amp_solve(y, a, prior, MPAMPConfig(30, t), ctrl, s0=s0)
    print(f"BT-MP-AMP   : SDR {sdr(bt.mse[-1]):6.2f} dB, "
          f"{bt.total_bits_empirical:6.2f} bits/elem (paper: 49.19)")

    dp = dp_allocate(prob, 30, t, 2.0 * t, rd=rd, mmse_fn=mm)
    deltas = DPSchedule(dp, rd, 30).deltas
    dps = mp_amp_solve(y, a, prior, MPAMPConfig(30, t), deltas, s0=s0,
                       sigma2_for_model=dp.sigma2_d[:-1])
    print(f"DP-MP-AMP   : SDR {sdr(dps.mse[-1]):6.2f} dB, "
          f"{dps.total_bits_empirical:6.2f} bits/elem (paper: 22.55)")


def part2_mesh_solver():
    print("\n=== Part 2: SPMD mesh solver (8 devices, int8 fusion) ===")
    from repro.compat import make_mesh
    prior = BernoulliGauss(eps=0.1)
    prob = CSProblem(n=4000, m=1200, prior=prior)
    s0, a, y = sample_problem(jax.random.PRNGKey(1), prob.n, prob.m, prior,
                              prob.sigma_e2)
    mesh = make_mesh((8,), ("data",))
    sdr = lambda x: 10 * np.log10(prior.second_moment / np.mean((x - s0) ** 2))

    for label, scfg in [
        ("exact fusion        ", SolverConfig(n_iter=15, bits=None)),
        ("int8 compressed psum", SolverConfig(n_iter=15, bits=8)),
        ("int4 compressed psum", SolverConfig(n_iter=15, bits=4)),
        ("int8 + 15% straggler", SolverConfig(n_iter=15, bits=8,
                                              drop_rate=0.15)),
    ]:
        x, _, nv = DistributedMPAMP(mesh, prior, scfg).solve(a, y)
        wire = {None: "32-bit", 8: "~8-bit", 4: "~4-bit"}[scfg.bits]
        print(f"{label}: SDR {sdr(x):6.2f} dB  (wire {wire}, "
              f"quant-noise var {np.asarray(nv).mean():.2e})")


if __name__ == "__main__":
    part1_paper_experiment()
    part2_mesh_solver()
