"""Observe a serving run end to end: per-request trace spans, the live
SE-drift monitor, and the Prometheus metrics snapshot (DESIGN.md §12).

Runs a mixed load through a telemetry-enabled ``SolveService``, prints
each request's span tree and SE drift, renders the service's metrics
registry as Prometheus text, and writes a Chrome trace
(``chrome://tracing`` / Perfetto) of the whole run.

  PYTHONPATH=src python examples/observe.py [--trace-out amp_trace.jsonl]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.state_evolution import CSProblem
from repro.serving import BucketPolicy, SolveRequest, SolveService
from repro.telemetry import (DRIFT_ALERT, hist_quantile, span_names,
                             write_trace_jsonl)

# Three operating points; the middle one lies about its SNR by 20 dB,
# so the drift monitor should flag it while the honest requests sit
# well under the alert line.
SPECS = [
    (0.10, 20.0, 20.0, 1024, 320, 8, 8),    # honest
    (0.10, 20.0,  0.0, 1024, 320, 8, 8),    # declares 0 dB, signal is 20
    (0.02, 25.0, 25.0,  512, 160, 4, 8),    # honest
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write Chrome trace-event JSONL of the run")
    args = ap.parse_args()

    svc = SolveService(policy=BucketPolicy(max_batch=32), telemetry=True)
    reqs = []
    for i, (eps, snr_true, snr_decl, n, m, p, t) in enumerate(SPECS):
        prior = BernoulliGauss(eps=eps)
        prob = CSProblem(n=n, m=m, prior=prior, snr_db=snr_true)
        _, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                 prob.sigma_e2)
        reqs.append(SolveRequest(y=y, a=a, prior=prior, snr_db=snr_decl,
                                 n_proc=p, n_iter=t, policy="lossless"))

    results = svc.solve(reqs)

    print("request trace spans + SE drift:")
    for spec, res in zip(SPECS, results):
        _, snr_true, snr_decl, n, m, p, t = spec
        tree = " -> ".join(span_names(res.spans))
        drift = ("   n/a" if res.se_drift is None
                 else f"{res.se_drift:6.3f}")
        flag = (" <-- ALERT (declared SNR is wrong)"
                if res.se_drift is not None and res.se_drift > DRIFT_ALERT
                else "")
        print(f"  N={n:5d} snr_decl={snr_decl:4.1f} (true {snr_true:4.1f})"
              f"  drift {drift}{flag}")
        print(f"    {tree}")
        for name, _, t0, t1 in res.spans:
            print(f"    {name:>10s}  {1e3 * (t1 - t0):8.3f} ms")

    snap = svc.metrics()
    for metric in snap["metrics"]:
        if metric["name"] != "amp_request_latency_seconds":
            continue
        for sample in metric["samples"]:
            p95 = hist_quantile(sample, 0.95)
            if p95 is not None:
                print(f"\nlatency p95 (histogram estimate): "
                      f"<= {1e3 * p95:.1f} ms")

    print("\nPrometheus snapshot (drift + request families):")
    for line in svc.metrics_text().splitlines():
        if "se_drift" in line or "requests_total" in line:
            print(f"  {line}")

    if args.trace_out:
        with open(args.trace_out, "w") as fp:
            n_ev = write_trace_jsonl(fp, results)
        print(f"\ntrace: {n_ev} span events -> {args.trace_out}")


if __name__ == "__main__":
    main()
