"""Serve a heterogeneous batch of CS recovery requests through the AMP
solve service — different shapes, priors, SNRs and rate policies mixed in
one submission (DESIGN.md §5).

  PYTHONPATH=src python examples/serve_mixed.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.core.amp import sample_problem
from repro.core.denoisers import BernoulliGauss
from repro.core.state_evolution import CSProblem
from repro.serving import BucketPolicy, SolveRequest, SolveService

# Mixed traffic: (eps, snr_db, N, M, P, T, policy) — different operating
# points, three rate policies, and both partition layouts: wide shapes
# (N/M ~ 3.2) route row-wise, tall ones (N/M >= 4) route to C-MP-AMP
# column buckets (DESIGN.md §7).
SPECS = [
    (0.10, 20.0, 1024, 320, 8, 8, "lossless"),
    (0.10, 20.0, 1024, 320, 8, 8, "fixed"),
    (0.02, 20.0, 2048, 256, 8, 10, "bt"),       # tall: column layout
    (0.10, 15.0,  512, 160, 4, 8, "bt"),
    (0.02, 25.0, 2048, 256, 8, 6, "fixed"),     # tall: column layout
]


def main():
    svc = SolveService(policy=BucketPolicy(max_batch=32))
    reqs, truths = [], []
    for i, (eps, snr, n, m, p, t, policy) in enumerate(SPECS):
        prior = BernoulliGauss(eps=eps)
        prob = CSProblem(n=n, m=m, prior=prior, snr_db=snr)
        s0, a, y = sample_problem(jax.random.PRNGKey(i), n, m, prior,
                                  prob.sigma_e2)
        kw = {}
        if policy == "fixed":
            deltas = np.full(t, 0.05, np.float32)
            deltas[0] = np.inf  # first iteration lossless (messages wide)
            kw["deltas"] = deltas
        reqs.append(SolveRequest(y=y, a=a, prior=prior, snr_db=snr,
                                 n_proc=p, n_iter=t, policy=policy, **kw))
        truths.append((s0, prob))

    results = svc.solve(reqs)

    print(f"{'policy':>9s} {'eps':>5s} {'snr':>5s} {'N':>5s} {'P':>3s} "
          f"{'T':>3s} {'SDR(dB)':>8s} {'bits/unit':>10s} {'bucket':>20s}")
    for (spec, res, (s0, prob)) in zip(SPECS, results, truths):
        eps, snr, n, m, p, t, policy = spec
        final_sdr = 10 * np.log10(prob.prior.second_moment
                                  / max(res.mse(s0), 1e-30))
        # rate units differ per layout: bits/signal-element (row) vs
        # bits/measurement (col) — the bucket's layout letter disambiguates
        bits = f"{res.total_bits:10.2f}" if res.tracked else "  lossless"
        bk = (f"({res.bucket.n_pad},{res.bucket.m_pad},"
              f"{res.bucket.n_proc},{res.bucket.t_max})"
              f"{res.bucket.layout[0]}")
        print(f"{policy:>9s} {eps:5.2f} {snr:5.1f} {n:5d} {p:3d} {t:3d} "
              f"{final_sdr:8.2f} {bits} {bk:>20s}")
    n_buckets = len({r.bucket for r in results})
    print(f"\n{len(reqs)} requests ran as {n_buckets} bucketed engine "
          f"calls; per-request results unpadded back to native shapes.")


if __name__ == "__main__":
    main()
