"""End-to-end LM training driver: data pipeline -> model -> AdamW -> ckpt.

Trains a granite-family decoder (defaults sized for the CPU container:
~10M params, 150 steps; pass --scale 100m for the ~100M-param configuration
on real hardware) through the full production stack: sharded synthetic data,
scan-over-layers transformer, chunked cross-entropy, ZeRO-sharded AdamW,
async checkpointing, NaN-step rejection and resume-on-restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 150] [--scale 10m]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainStepConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

SCALES = {
    # name: (layers, d_model, heads, kv, d_head, d_ff, vocab, seq, batch)
    "10m": (6, 320, 8, 4, 40, 1024, 8192, 128, 8),
    "100m": (12, 768, 12, 4, 64, 2048, 32000, 512, 32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--scale", choices=SCALES, default="10m")
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_train_lm")
    args = ap.parse_args()

    l, d, h, kv, dh, f, v, seq, batch = SCALES[args.scale]
    cfg = ModelConfig(name=f"lm-{args.scale}", family="dense", n_layers=l,
                      d_model=d, n_heads=h, n_kv_heads=kv, d_head=dh,
                      d_ff=f, vocab=v)
    shape = ShapeSpec("train", seq, batch, "train")
    mesh = make_host_mesh(model=1)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params, {l}L d={d}, "
          f"batch {batch} x seq {seq}, {args.steps} steps")

    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt,
        log_every=10,
        step_cfg=TrainStepConfig(
            microbatches=2, moe_groups=1,
            adamw=AdamWConfig(lr=1e-3, weight_decay=0.01)))
    trainer = Trainer(cfg, shape, mesh, tcfg)
    _, _, hist = trainer.run(resume=True)

    losses = [h["loss"] for h in hist]
    print(f"\nloss: first10={np.mean(losses[:10]):.3f} "
          f"last10={np.mean(losses[-10:]):.3f} "
          f"(improvement {np.mean(losses[:10]) - np.mean(losses[-10:]):.3f})")
    print(f"checkpoints under {args.ckpt}")


if __name__ == "__main__":
    main()
