"""rANS wire demo: close the loop on the paper's rate claims end-to-end.

The paper accounts coding rate as the ECSQ entropy H_Q, "achievable
through entropy coding".  ``core/entropy_code.RansCodec`` proves that on
host; this demo wires it into the serving-path solver: run a BT-MP-AMP
solve, take the *realized* quantizer symbol streams of every iteration,
entropy-code them per processor with the rANS coder, and compare

    actual rANS bits  vs  empirical entropy  vs  model H_Q  vs  int8 wire

per iteration and in total.  The actual bitstream lands within a few
bytes/processor of the empirical entropy (static-model rANS overhead:
state flush + frequency quantization), which in turn tracks the model
H_Q the BT controller optimizes — so the paper's rate numbers are bytes
you could put on a real wire, not an idealization.  The int8 column is
what the fixed-width TPU transport (DESIGN.md §2) would spend instead.

  PYTHONPATH=src python examples/wire_demo.py [--smoke]

``--smoke`` shrinks the problem for CI; its assertions make this demo a
regression check on the whole accounting chain (symbols -> codec ->
bytes -> H_Q).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import numpy as np


def empirical_entropy(sym: np.ndarray) -> float:
    _, counts = np.unique(sym.astype(np.int64), return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small problem + assertions (CI wire-accounting "
                         "regression)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.core.amp import sample_problem
    from repro.core.denoisers import BernoulliGauss
    from repro.core.engine import (AmpEngine, BTRateControl, EcsqTransport,
                                   EngineConfig)
    from repro.core.entropy_code import RansCodec
    from repro.core.state_evolution import CSProblem

    if args.smoke:
        n, m, p, t = 800, 240, 6, 6
    else:
        n, m, p, t = 2000, 600, 10, 10   # kappa 0.3, the paper's Sec. 4 op
    prior = BernoulliGauss(eps=0.05)
    prob = CSProblem(n=n, m=m, prior=prior, snr_db=20.0)
    s0, a, y = sample_problem(jax.random.PRNGKey(args.seed), n, m, prior,
                              prob.sigma_e2)

    ctrl = BTRateControl(prob, p, t, c_ratio=1.005, r_max=6.0)
    eng = AmpEngine(prior,
                    EngineConfig(n_proc=p, n_iter=t, collect_symbols=True,
                                 collect_xs=True),
                    EcsqTransport(), ctrl)
    tr = eng.solve(y, a)
    print(f"BT-MP-AMP solve: N={n} M={m} P={p} T={t} eps=0.05 20dB  "
          f"final MSE {float(tr.mse(s0)[-1]):.3e}")

    int8_wire = 8.0 + 16.0 / 512   # int8 + amortized bf16 scale per block
    print(f"\n{'t':>3s} {'delta':>9s} {'H_Q model':>10s} {'H_emp':>8s} "
          f"{'rANS':>8s} {'int8 wire':>10s}   (bits/elem/proc)")
    tot_hq = tot_emp = tot_rans = 0.0
    checked_roundtrip = False
    for it in range(t):
        if not np.isfinite(tr.deltas[it]):
            print(f"{it:3d} {'lossless':>9s}")
            continue
        syms = np.asarray(tr.symbols[it], np.int64)       # (P, N)
        # per-processor streams: each processor codes its own messages
        # with its own static model, exactly what the cluster would do
        bits = 0
        for proc in range(p):
            stream = syms[proc]
            lo = stream.min()
            shifted = stream - lo                          # rANS alphabet
            codec = RansCodec(np.bincount(shifted))
            bits += codec.encoded_bits(shifted)
            if not checked_roundtrip:
                enc = codec.encode(shifted)
                dec = codec.decode(enc, len(shifted))
                assert (dec == shifted).all(), "rANS round-trip failed"
                checked_roundtrip = True
        r_rans = bits / (p * n)
        r_emp = float(np.mean([empirical_entropy(syms[q])
                               for q in range(p)]))
        r_hq = float(tr.rates[it])
        print(f"{it:3d} {tr.deltas[it]:9.4f} {r_hq:10.3f} {r_emp:8.3f} "
              f"{r_rans:8.3f} {int8_wire:10.3f}")
        tot_hq += r_hq
        tot_emp += r_emp
        tot_rans += r_rans
        if args.smoke:
            # the paper's claim, as inequalities on realized bytes: the
            # coder may not beat the empirical entropy of its own stream,
            # and its overhead is a few bytes/processor (state flush +
            # 12-bit frequency table quantization)
            assert r_rans >= r_emp - 1e-6, (it, r_rans, r_emp)
            assert r_rans <= r_emp + 0.1 + 64.0 * 8 / n, (it, r_rans, r_emp)

    n_coded = int(np.isfinite(tr.deltas).sum())
    print(f"\ntotals over {n_coded} coded iterations: "
          f"H_Q {tot_hq:.2f}, empirical {tot_emp:.2f}, "
          f"rANS {tot_rans:.2f}, int8 wire {n_coded * int8_wire:.2f}")
    if tot_rans > 0:
        print(f"rANS spends {tot_rans / tot_hq:.3f}x the model H_Q and "
              f"{tot_rans / (n_coded * int8_wire):.2f}x the int8 wire "
              f"({n_coded * int8_wire / tot_rans:.1f}x saving vs "
              f"fixed-width transport)")
    if args.smoke:
        assert checked_roundtrip
        assert tot_rans > 0
        print("smoke assertions passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
