"""Quickstart: compressed-sensing recovery with MP-AMP + lossy fusion.

Solves y = A s0 + e with 30 emulated processors, comparing:
  * centralized AMP (paper eqs. 1-3),
  * MP-AMP with lossless fusion (bit-identical to centralized),
  * MP-AMP with BT-controlled ECSQ quantization (paper Sec. 3.3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.amp import amp_solve, sample_problem
from repro.core.denoisers import BernoulliGauss, make_mmse_interp
from repro.core.mp_amp import MPAMPConfig, mp_amp_solve
from repro.core.rate_alloc import BTController
from repro.core.state_evolution import CSProblem


def main():
    prior = BernoulliGauss(eps=0.1, mu_s=0.0, sigma_s=1.0)
    prob = CSProblem(n=5000, m=1500, prior=prior, snr_db=20.0)
    t = 15
    print(f"CS problem: N={prob.n} M={prob.m} eps={prior.eps} "
          f"SNR={prob.snr_db}dB, P=30 processors, T={t}")

    s0, a, y = sample_problem(jax.random.PRNGKey(0), prob.n, prob.m, prior,
                              prob.sigma_e2)
    sdr = lambda mse: 10 * np.log10(prior.second_moment / mse)

    cen = amp_solve(y, a, prior, t, s0=s0)
    print(f"\ncentralized AMP       : SDR {sdr(cen.mse[-1]):6.2f} dB "
          f"(32-bit fusion: {32 * t} bits/element total)")

    lossless = mp_amp_solve(y, a, prior, MPAMPConfig(30, t), [np.inf] * t, s0=s0)
    print(f"MP-AMP lossless fusion: SDR {sdr(lossless.mse[-1]):6.2f} dB "
          f"(identical to centralized: max|dx|="
          f"{np.abs(lossless.x - cen.x).max():.1e})")

    ctrl = BTController(prob, 30, t, c_ratio=1.005, r_max=6.0,
                        rate_model="ecsq", mmse_fn=make_mmse_interp(prior))
    bt = mp_amp_solve(y, a, prior, MPAMPConfig(30, t), ctrl, s0=s0)
    total = bt.total_bits_empirical
    print(f"BT-MP-AMP (ECSQ)      : SDR {sdr(bt.mse[-1]):6.2f} dB "
          f"({total:.1f} bits/element total -> "
          f"{100 * (1 - total / (32 * t)):.0f}% communication saved)")
    print("per-iteration rates   :", np.round(bt.rates_empirical, 2))


if __name__ == "__main__":
    main()
